//! Application layer: the `Generator` — "plugin everything" (§III-A.3).
//!
//! A generator owns a function tree (Definition), a typed parameter struct,
//! and a set of plugins (Implementation). [`Generator::elaborate`] runs the
//! three blocking stages across all plugins, validates function coverage
//! and netlist structure, and produces an [`Elaborated`] artifact
//! (Generation). Plugging/unplugging between elaborations is the paper's
//! central agility claim, and [`StageTrace`] records per-plugin stage
//! timings for the Fig. 6d productivity experiments.

use std::time::Instant;

use super::error::DiagError;
use super::plugin::{ElabCtx, Plugin, Stage, Target};
use super::service::ServiceRegistry;
use super::spec::FunctionTree;
use crate::netlist::Netlist;

/// One timed plugin-stage execution.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub plugin: String,
    pub stage: Stage,
    pub nanos: u128,
}

/// Elaboration timing trace.
#[derive(Debug, Clone, Default)]
pub struct StageTrace {
    pub events: Vec<TraceEvent>,
}

impl StageTrace {
    pub fn total_nanos(&self) -> u128 {
        self.events.iter().map(|e| e.nanos).sum()
    }

    pub fn per_plugin_nanos(&self, plugin: &str) -> u128 {
        self.events.iter().filter(|e| e.plugin == plugin).map(|e| e.nanos).sum()
    }
}

/// The Generation-layer output.
pub struct Elaborated<T: Target> {
    /// Parameters after `create_config` adjustments.
    pub params: T::Params,
    /// Target-specific artifact (for WindMill: the machine description the
    /// cycle-accurate simulator executes).
    pub artifact: T::Artifact,
    /// Structural netlist (render with `netlist::verilog::emit`).
    pub netlist: Netlist,
    /// Extension fragments left unimplemented (zero-residue by design).
    pub skipped_extensions: Vec<String>,
    /// Per-plugin stage timings.
    pub trace: StageTrace,
    /// Total service registrations during elaboration.
    pub service_registrations: usize,
}

/// A pluggable, parameterized hardware generator.
pub struct Generator<T: Target> {
    tree: FunctionTree,
    params: T::Params,
    plugins: Vec<Box<dyn Plugin<T>>>,
}

impl<T: Target> Generator<T> {
    pub fn new(tree: FunctionTree, params: T::Params) -> Self {
        Generator { tree, params, plugins: Vec::new() }
    }

    /// Add a plugin; names must be unique within the generator.
    pub fn plug(&mut self, plugin: Box<dyn Plugin<T>>) -> Result<&mut Self, DiagError> {
        if self.has(plugin.name()) {
            return Err(DiagError::DuplicatePlugin(plugin.name().to_string()));
        }
        self.plugins.push(plugin);
        Ok(self)
    }

    /// Builder-style `plug` that panics on duplicates (preset assembly).
    pub fn with(mut self, plugin: Box<dyn Plugin<T>>) -> Self {
        self.plug(plugin).map(|_| ()).unwrap_or_else(|e| panic!("{e}"));
        self
    }

    /// Remove a plugin by name; returns whether it was present. This is the
    /// paper's "detach" operation — the next elaboration re-binds service
    /// chains around the hole with no residual logic.
    pub fn unplug(&mut self, name: &str) -> bool {
        let before = self.plugins.len();
        self.plugins.retain(|p| p.name() != name);
        self.plugins.len() != before
    }

    pub fn has(&self, name: &str) -> bool {
        self.plugins.iter().any(|p| p.name() == name)
    }

    pub fn plugin_names(&self) -> Vec<&'static str> {
        self.plugins.iter().map(|p| p.name()).collect()
    }

    pub fn plugin_count(&self) -> usize {
        self.plugins.len()
    }

    pub fn params(&self) -> &T::Params {
        &self.params
    }

    pub fn params_mut(&mut self) -> &mut T::Params {
        &mut self.params
    }

    pub fn tree(&self) -> &FunctionTree {
        &self.tree
    }

    /// Run the three blocking elaboration stages and produce the artifact.
    ///
    /// Re-entrant: plugins recreate per-run state in `create_early`, so a
    /// generator can be elaborated repeatedly (possibly with parameter or
    /// plugin-set changes in between — the calibration feedback loop).
    pub fn elaborate(&mut self) -> Result<Elaborated<T>, DiagError> {
        let mut trace = StageTrace::default();

        // Definition-layer validation: coverage of the function tree.
        let implemented: Vec<(String, String)> = self
            .plugins
            .iter()
            .map(|p| (p.name().to_string(), p.function().to_string()))
            .collect();
        let skipped_extensions = self.tree.validate(&implemented)?;

        // Stage 1 (blocking): create_config over a params copy.
        let mut params = self.params.clone();
        for p in self.plugins.iter_mut() {
            let t0 = Instant::now();
            p.create_config(&mut params)?;
            trace.events.push(TraceEvent {
                plugin: p.name().to_string(),
                stage: Stage::Config,
                nanos: t0.elapsed().as_nanos(),
            });
        }

        // Stages 2 and 3 (each blocking) share one registry/netlist/artifact.
        let mut services = ServiceRegistry::new();
        let mut netlist = Netlist::new();
        let mut artifact = T::Artifact::default();

        for stage in [Stage::Early, Stage::Late] {
            for p in self.plugins.iter_mut() {
                let t0 = Instant::now();
                let mut ctx = ElabCtx::<T> {
                    services: &mut services,
                    netlist: &mut netlist,
                    artifact: &mut artifact,
                    current_plugin: p.name().to_string(),
                    stage,
                };
                match stage {
                    Stage::Early => p.create_early(&params, &mut ctx)?,
                    Stage::Late => p.create_late(&params, &mut ctx)?,
                    Stage::Config => unreachable!(),
                }
                trace.events.push(TraceEvent {
                    plugin: p.name().to_string(),
                    stage,
                    nanos: t0.elapsed().as_nanos(),
                });
            }
        }

        netlist.validate()?;

        Ok(Elaborated {
            params,
            artifact,
            netlist,
            skipped_extensions,
            trace,
            service_registrations: services.total_registrations(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diag::plugin::ElabCtx;
    use crate::diag::spec::FunctionKind;
    use crate::netlist::Module;
    use std::cell::RefCell;
    use std::rc::Rc;

    // --- a toy target: a counter chain with an optional filter stage ----
    struct Toy;
    #[derive(Clone, Default)]
    struct ToyParams {
        width: u32,
    }
    #[derive(Default)]
    struct ToyMachine {
        stages: Vec<&'static str>,
    }
    impl Target for Toy {
        type Params = ToyParams;
        type Artifact = ToyMachine;
    }

    /// Service: a pipeline stage in the Fig. 3 chain.
    struct PipeStage {
        name: &'static str,
    }

    struct SourcePlugin;
    impl Plugin<Toy> for SourcePlugin {
        fn name(&self) -> &'static str {
            "source"
        }
        fn function(&self) -> &'static str {
            "chain/source"
        }
        fn create_config(&mut self, p: &mut ToyParams) -> Result<(), DiagError> {
            if p.width == 0 {
                p.width = 8; // defaulting during config stage
            }
            Ok(())
        }
        fn create_early(&mut self, _p: &ToyParams, ctx: &mut ElabCtx<Toy>) -> Result<(), DiagError> {
            ctx.provide(30, Rc::new(PipeStage { name: "source" }));
            let mut m = Module::new("source", "");
            m.output("o", 8);
            ctx.add_module(m)
        }
    }

    struct FilterPlugin;
    impl Plugin<Toy> for FilterPlugin {
        fn name(&self) -> &'static str {
            "filter"
        }
        fn function(&self) -> &'static str {
            "chain/filter"
        }
        fn create_early(&mut self, _p: &ToyParams, ctx: &mut ElabCtx<Toy>) -> Result<(), DiagError> {
            ctx.provide(20, Rc::new(PipeStage { name: "filter" }));
            let mut m = Module::new("filter", "");
            m.input("i", 8).output("o", 8);
            ctx.add_module(m)
        }
    }

    struct SinkPlugin;
    impl Plugin<Toy> for SinkPlugin {
        fn name(&self) -> &'static str {
            "sink"
        }
        fn function(&self) -> &'static str {
            "chain/sink"
        }
        fn create_early(&mut self, _p: &ToyParams, ctx: &mut ElabCtx<Toy>) -> Result<(), DiagError> {
            ctx.provide(10, Rc::new(PipeStage { name: "sink" }));
            let mut m = Module::new("sink", "");
            m.input("i", 8);
            ctx.add_module(m)
        }
        fn create_late(&mut self, _p: &ToyParams, ctx: &mut ElabCtx<Toy>) -> Result<(), DiagError> {
            // Assemble the top by wiring through whatever stages exist —
            // the Fig. 3 detach-rebind behaviour under test.
            let chain = ctx.service_chain::<PipeStage>();
            for s in &chain {
                ctx.artifact.stages.push(s.name);
            }
            let mut top = Module::new("top", "");
            top.input("clk", 1);
            for (i, w) in chain.windows(2).enumerate() {
                top.wire(&format!("n{i}"), 8);
                let _ = w;
            }
            // Instantiate each stage connected to its neighbour nets.
            for (i, s) in chain.iter().enumerate() {
                let mut conns: Vec<(String, String)> = Vec::new();
                if i > 0 {
                    conns.push(("i".to_string(), format!("n{}", i - 1)));
                }
                if i + 1 < chain.len() {
                    conns.push(("o".to_string(), format!("n{i}")));
                }
                let cs: Vec<(&str, &str)> =
                    conns.iter().map(|(a, b)| (a.as_str(), b.as_str())).collect();
                top.instance(&format!("u_{}", s.name), s.name, &cs);
            }
            ctx.add_module(top)?;
            ctx.set_top("top");
            Ok(())
        }
    }

    fn toy_tree() -> FunctionTree {
        let mut t = FunctionTree::new();
        t.basic("chain/source").basic("chain/sink");
        t.declare("chain/filter", FunctionKind::Extension);
        t
    }

    fn full_gen() -> Generator<Toy> {
        Generator::new(toy_tree(), ToyParams::default())
            .with(Box::new(SourcePlugin))
            .with(Box::new(FilterPlugin))
            .with(Box::new(SinkPlugin))
    }

    #[test]
    fn elaborates_full_chain() {
        let e = full_gen().elaborate().unwrap();
        assert_eq!(e.artifact.stages, vec!["source", "filter", "sink"]);
        assert_eq!(e.params.width, 8); // config-stage defaulting ran
        assert!(e.skipped_extensions.is_empty());
        e.netlist.validate().unwrap();
    }

    #[test]
    fn unplug_rebinds_chain_with_no_residue() {
        let mut g = full_gen();
        assert!(g.unplug("filter"));
        let e = g.elaborate().unwrap();
        // A -> C: the sink now connects straight to the source.
        assert_eq!(e.artifact.stages, vec!["source", "sink"]);
        // Zero residual logic from the filter plugin.
        assert!(e.netlist.find("filter").is_none());
        assert!(e.netlist.by_provenance("filter").is_empty());
        assert_eq!(e.skipped_extensions, vec!["chain/filter"]);
        e.netlist.validate().unwrap();
    }

    #[test]
    fn duplicate_plugin_rejected() {
        let mut g = full_gen();
        let err = g.plug(Box::new(SourcePlugin)).err().unwrap();
        assert!(matches!(err, DiagError::DuplicatePlugin(_)));
    }

    #[test]
    fn missing_basic_function_fails() {
        let mut g = Generator::<Toy>::new(toy_tree(), ToyParams::default())
            .with(Box::new(SourcePlugin));
        let err = g.elaborate().map(|_| ()).unwrap_err();
        assert!(matches!(err, DiagError::MissingFunction { .. }));
    }

    #[test]
    fn trace_records_all_stages() {
        let mut g = full_gen();
        let e = g.elaborate().unwrap();
        // 3 plugins x 3 stages.
        assert_eq!(e.trace.events.len(), 9);
        assert!(e.trace.total_nanos() > 0);
        assert!(e.trace.per_plugin_nanos("sink") > 0);
    }

    #[test]
    fn elaboration_is_reentrant() {
        let mut g = full_gen();
        let a = g.elaborate().unwrap();
        let b = g.elaborate().unwrap();
        assert_eq!(a.artifact.stages, b.artifact.stages);
        assert_eq!(a.netlist.module_names(), b.netlist.module_names());
    }

    #[test]
    fn service_registrations_counted() {
        let e = full_gen().elaborate().unwrap();
        assert_eq!(e.service_registrations, 3);
    }

    // A plugin whose late stage requires a service nobody provides.
    struct NeedyPlugin;
    struct GhostService;
    impl Plugin<Toy> for NeedyPlugin {
        fn name(&self) -> &'static str {
            "needy"
        }
        fn function(&self) -> &'static str {
            "chain/source"
        }
        fn create_late(&mut self, _p: &ToyParams, ctx: &mut ElabCtx<Toy>) -> Result<(), DiagError> {
            ctx.get_service::<GhostService>().map(|_| ())
        }
    }

    #[test]
    fn missing_service_is_attributed() {
        let mut g = Generator::<Toy>::new(toy_tree(), ToyParams::default())
            .with(Box::new(NeedyPlugin))
            .with(Box::new(SinkPlugin));
        let err = g.elaborate().map(|_| ()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("needy"), "{msg}");
        assert!(msg.contains("create_late"), "{msg}");
        assert!(msg.contains("GhostService"), "{msg}");
    }

    #[test]
    fn shared_refcell_service_across_plugins() {
        // Ensures the registry supports the mutable-shared-state pattern the
        // WindMill plugins use for port aggregation.
        struct Collector(RefCell<Vec<&'static str>>);
        struct P1;
        impl Plugin<Toy> for P1 {
            fn name(&self) -> &'static str {
                "p1"
            }
            fn function(&self) -> &'static str {
                "chain/source"
            }
            fn create_early(&mut self, _p: &ToyParams, ctx: &mut ElabCtx<Toy>) -> Result<(), DiagError> {
                ctx.provide(0, Rc::new(Collector(RefCell::new(vec![]))));
                let mut m = Module::new("top", "");
                m.input("clk", 1);
                ctx.add_module(m)?;
                ctx.set_top("top");
                Ok(())
            }
        }
        struct P2;
        impl Plugin<Toy> for P2 {
            fn name(&self) -> &'static str {
                "p2"
            }
            fn function(&self) -> &'static str {
                "chain/sink"
            }
            fn create_late(&mut self, _p: &ToyParams, ctx: &mut ElabCtx<Toy>) -> Result<(), DiagError> {
                let c = ctx.get_service::<Collector>()?;
                c.0.borrow_mut().push("p2-was-here");
                Ok(())
            }
        }
        let mut g = Generator::<Toy>::new(toy_tree(), ToyParams::default())
            .with(Box::new(P1))
            .with(Box::new(P2));
        g.elaborate().unwrap();
    }
}
