//! Typed service registry — the "Service" half of Function-Plugin-Service.
//!
//! Plugins never hold references to each other. A provider publishes a
//! service object (usually `Rc<RefCell<...>>` shared state or a descriptor
//! of netlist connection points); consumers look it up **by type** with
//! [`ServiceRegistry::get`], mirroring SpinalHDL's `getService[...]`.
//!
//! Multiple providers of one service type form a *priority chain*
//! ([`ServiceRegistry::chain`]). This is the mechanism behind the paper's
//! Fig. 3 detachment semantics: a consumer that wires "through" the chain
//! automatically connects `A → C` when the `B` plugin is unplugged, with no
//! residual logic, because the binding is computed from whichever providers
//! are actually present.

use std::any::{type_name, Any, TypeId};
use std::collections::HashMap;
use std::rc::Rc;

use super::error::DiagError;

struct ProviderEntry {
    plugin: String,
    priority: i32,
    /// Insertion order tiebreak for equal priorities (stable chains).
    seq: usize,
    service: Rc<dyn Any>,
}

/// Registry of service providers, keyed by service type.
#[derive(Default)]
pub struct ServiceRegistry {
    by_type: HashMap<TypeId, Vec<ProviderEntry>>,
    seq: usize,
}

impl ServiceRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish a service. Higher `priority` sorts earlier in the chain;
    /// the highest-priority provider is what `get` returns.
    pub fn register<T: Any>(&mut self, plugin: &str, priority: i32, service: Rc<T>) {
        let entry = ProviderEntry {
            plugin: plugin.to_string(),
            priority,
            seq: self.seq,
            service: service as Rc<dyn Any>,
        };
        self.seq += 1;
        let v = self.by_type.entry(TypeId::of::<T>()).or_default();
        v.push(entry);
        v.sort_by(|a, b| b.priority.cmp(&a.priority).then(a.seq.cmp(&b.seq)));
    }

    /// Highest-priority provider of `T`, if any.
    pub fn try_get<T: Any>(&self) -> Option<Rc<T>> {
        self.by_type
            .get(&TypeId::of::<T>())?
            .first()
            .map(|e| Rc::downcast::<T>(Rc::clone(&e.service)).expect("typeid match"))
    }

    /// Highest-priority provider of `T`, or a `MissingService` error
    /// attributed to `wanted_by`/`stage` (for actionable diagnostics).
    pub fn get<T: Any>(&self, wanted_by: &str, stage: &'static str) -> Result<Rc<T>, DiagError> {
        self.try_get::<T>().ok_or(DiagError::MissingService {
            service: type_name::<T>(),
            wanted_by: wanted_by.to_string(),
            stage,
        })
    }

    /// All providers of `T`, priority-descending — the Fig. 3 chain.
    pub fn chain<T: Any>(&self) -> Vec<Rc<T>> {
        self.by_type
            .get(&TypeId::of::<T>())
            .map(|v| {
                v.iter()
                    .map(|e| Rc::downcast::<T>(Rc::clone(&e.service)).expect("typeid match"))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Names of the plugins providing `T`, priority-descending.
    pub fn providers<T: Any>(&self) -> Vec<String> {
        self.by_type
            .get(&TypeId::of::<T>())
            .map(|v| v.iter().map(|e| e.plugin.clone()).collect())
            .unwrap_or_default()
    }

    pub fn count<T: Any>(&self) -> usize {
        self.by_type.get(&TypeId::of::<T>()).map_or(0, Vec::len)
    }

    /// Total number of (type, provider) registrations — a productivity
    /// metric surfaced by the Fig. 6d bench.
    pub fn total_registrations(&self) -> usize {
        self.by_type.values().map(Vec::len).sum()
    }
}

/// Design-space-exploration capability descriptor.
///
/// The coordinator's `SweepEngine` publishes one of these
/// (`SweepEngine::register_service`) so Application-layer tooling can
/// discover sweep capability through the same typed-service mechanism
/// plugins use for hardware — `registry.get::<SweepService>(...)` — instead
/// of hard-wiring a coordinator dependency. Living in the DIAG layer keeps
/// the descriptor target-agnostic: any generator flow can advertise a DSE
/// backend under this type.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepService {
    /// Implementation identifier (e.g. `"coordinator::SweepEngine"`).
    pub provider: &'static str,
    /// Worker threads backing the engine.
    pub workers: usize,
    /// Lockstep simulation batch width: grid points are dispatched in
    /// chunks of this size and same-DFG phases across a chunk run as lanes
    /// of one simulation arena (`1` = per-point dispatch).
    pub batch: usize,
    /// Whether evaluations are memoized across sweep points.
    pub cached: bool,
    /// Whether the memo survives the process (a persistent artifact store
    /// is attached, so warm starts cross process/CI-run boundaries).
    pub persistent: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    struct MemPort(u32);
    #[derive(Debug)]
    struct CfgBus;

    #[test]
    fn register_and_get() {
        let mut r = ServiceRegistry::new();
        r.register("sm", 0, Rc::new(MemPort(16)));
        let p = r.get::<MemPort>("lsu", "create_late").unwrap();
        assert_eq!(*p, MemPort(16));
    }

    #[test]
    fn missing_service_names_the_consumer() {
        let r = ServiceRegistry::new();
        let err = r.get::<CfgBus>("fetch", "create_late").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("CfgBus"), "{msg}");
        assert!(msg.contains("fetch"), "{msg}");
    }

    #[test]
    fn priority_selects_provider() {
        let mut r = ServiceRegistry::new();
        r.register("base", 0, Rc::new(MemPort(1)));
        r.register("override", 10, Rc::new(MemPort(2)));
        assert_eq!(*r.try_get::<MemPort>().unwrap(), MemPort(2));
    }

    #[test]
    fn chain_orders_by_priority_then_insertion() {
        let mut r = ServiceRegistry::new();
        r.register("a", 5, Rc::new(MemPort(1)));
        r.register("b", 9, Rc::new(MemPort(2)));
        r.register("c", 5, Rc::new(MemPort(3)));
        let ids: Vec<u32> = r.chain::<MemPort>().iter().map(|p| p.0).collect();
        assert_eq!(ids, vec![2, 1, 3]);
        assert_eq!(r.providers::<MemPort>(), vec!["b", "a", "c"]);
    }

    #[test]
    fn unplugging_rebinds_the_chain() {
        // Fig. 3: with B present the chain is A->B->C; without B it is A->C.
        let build = |with_b: bool| {
            let mut r = ServiceRegistry::new();
            r.register("stage-a", 30, Rc::new(MemPort(0xA)));
            if with_b {
                r.register("stage-b", 20, Rc::new(MemPort(0xB)));
            }
            r.register("stage-c", 10, Rc::new(MemPort(0xC)));
            r.chain::<MemPort>().iter().map(|p| p.0).collect::<Vec<_>>()
        };
        assert_eq!(build(true), vec![0xA, 0xB, 0xC]);
        assert_eq!(build(false), vec![0xA, 0xC]);
    }

    #[test]
    fn counts_and_registrations() {
        let mut r = ServiceRegistry::new();
        r.register("x", 0, Rc::new(MemPort(0)));
        r.register("y", 0, Rc::new(CfgBus));
        r.register("z", 0, Rc::new(CfgBus));
        assert_eq!(r.count::<MemPort>(), 1);
        assert_eq!(r.count::<CfgBus>(), 2);
        assert_eq!(r.total_registrations(), 3);
    }

    #[test]
    fn shared_mutable_service_state() {
        use std::cell::RefCell;
        let mut r = ServiceRegistry::new();
        r.register("prod", 0, Rc::new(RefCell::new(Vec::<u32>::new())));
        let a = r.try_get::<RefCell<Vec<u32>>>().unwrap();
        a.borrow_mut().push(7);
        let b = r.try_get::<RefCell<Vec<u32>>>().unwrap();
        assert_eq!(*b.borrow(), vec![7]);
    }
}
