//! Error taxonomy of the DIAG elaboration pipeline.
//!
//! `Display`/`Error` are implemented by hand: thiserror is not vendored on
//! this image (see `util/mod.rs`), and the coordinator ships these errors
//! across worker threads, so the type stays plain data (`Send + Sync`).

use std::fmt;

#[derive(Debug, Clone)]
pub enum DiagError {
    /// `get_service::<T>()` found no provider for a required service.
    MissingService {
        service: &'static str,
        wanted_by: String,
        stage: &'static str,
    },

    /// Two plugins with the same name were added to one generator.
    DuplicatePlugin(String),

    /// A required function-tree fragment has no implementing plugin.
    MissingFunction { path: String },

    /// A plugin names a function path that is not in the definition tree.
    UnknownFunction { plugin: String, path: String },

    /// A `Handle` was read before any stage loaded it.
    UnloadedHandle(String),

    /// A plugin reported a config/elaboration problem.
    PluginFailed {
        plugin: String,
        stage: &'static str,
        msg: String,
    },

    /// Netlist validation after create_late found structural problems.
    MalformedNetlist(String),

    /// Parameter validation failed during create_config.
    InvalidParams(String),

    /// Persistent artifact store problem (I/O, codec corruption, or a
    /// sweep-session shard/merge inconsistency).
    Store(String),

    /// The static analyzer found error-severity diagnostics; the mapping
    /// was rejected before any simulation (the pre-sim gate in
    /// `run_job_cached`).
    Verify(String),
}

impl fmt::Display for DiagError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DiagError::MissingService { service, wanted_by, stage } => write!(
                f,
                "no provider for service `{service}` (wanted by plugin `{wanted_by}` in stage {stage})"
            ),
            DiagError::DuplicatePlugin(name) => write!(f, "duplicate plugin `{name}`"),
            DiagError::MissingFunction { path } => write!(
                f,
                "function `{path}` is part of the basic framework but no plugin implements it"
            ),
            DiagError::UnknownFunction { plugin, path } => {
                write!(f, "plugin `{plugin}` implements unknown function `{path}`")
            }
            DiagError::UnloadedHandle(name) => {
                write!(f, "handle `{name}` read before it was loaded")
            }
            DiagError::PluginFailed { plugin, stage, msg } => {
                write!(f, "plugin `{plugin}` failed in {stage}: {msg}")
            }
            DiagError::MalformedNetlist(msg) => {
                write!(f, "generated netlist is malformed: {msg}")
            }
            DiagError::InvalidParams(msg) => write!(f, "invalid parameters: {msg}"),
            DiagError::Store(msg) => write!(f, "artifact store: {msg}"),
            DiagError::Verify(msg) => write!(f, "static check failed: {msg}"),
        }
    }
}

impl std::error::Error for DiagError {}

impl DiagError {
    /// Convenience constructor used by plugins.
    pub fn plugin(plugin: &str, stage: &'static str, msg: impl Into<String>) -> Self {
        DiagError::PluginFailed {
            plugin: plugin.to_string(),
            stage,
            msg: msg.into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_are_actionable() {
        let e = DiagError::MissingService {
            service: "windmill::MemPort",
            wanted_by: "lsu".into(),
            stage: "create_late",
        };
        let s = e.to_string();
        assert!(s.contains("windmill::MemPort"));
        assert!(s.contains("lsu"));
        assert!(s.contains("create_late"));
    }

    #[test]
    fn plugin_helper() {
        let e = DiagError::plugin("gpe", "create_early", "bad width");
        assert!(e.to_string().contains("gpe"));
        assert!(e.to_string().contains("bad width"));
    }
}
