//! Error taxonomy of the DIAG elaboration pipeline.

use thiserror::Error;

#[derive(Debug, Error)]
pub enum DiagError {
    /// `get_service::<T>()` found no provider for a required service.
    #[error("no provider for service `{service}` (wanted by plugin `{wanted_by}` in stage {stage})")]
    MissingService {
        service: &'static str,
        wanted_by: String,
        stage: &'static str,
    },

    /// Two plugins with the same name were added to one generator.
    #[error("duplicate plugin `{0}`")]
    DuplicatePlugin(String),

    /// A required function-tree fragment has no implementing plugin.
    #[error("function `{path}` is part of the basic framework but no plugin implements it")]
    MissingFunction { path: String },

    /// A plugin names a function path that is not in the definition tree.
    #[error("plugin `{plugin}` implements unknown function `{path}`")]
    UnknownFunction { plugin: String, path: String },

    /// A `Handle` was read before any stage loaded it.
    #[error("handle `{0}` read before it was loaded")]
    UnloadedHandle(String),

    /// A plugin reported a config/elaboration problem.
    #[error("plugin `{plugin}` failed in {stage}: {msg}")]
    PluginFailed {
        plugin: String,
        stage: &'static str,
        msg: String,
    },

    /// Netlist validation after create_late found structural problems.
    #[error("generated netlist is malformed: {0}")]
    MalformedNetlist(String),

    /// Parameter validation failed during create_config.
    #[error("invalid parameters: {0}")]
    InvalidParams(String),
}

impl DiagError {
    /// Convenience constructor used by plugins.
    pub fn plugin(plugin: &str, stage: &'static str, msg: impl Into<String>) -> Self {
        DiagError::PluginFailed {
            plugin: plugin.to_string(),
            stage,
            msg: msg.into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_are_actionable() {
        let e = DiagError::MissingService {
            service: "windmill::MemPort",
            wanted_by: "lsu".into(),
            stage: "create_late",
        };
        let s = e.to_string();
        assert!(s.contains("windmill::MemPort"));
        assert!(s.contains("lsu"));
        assert!(s.contains("create_late"));
    }

    #[test]
    fn plugin_helper() {
        let e = DiagError::plugin("gpe", "create_early", "bad width");
        assert!(e.to_string().contains("gpe"));
        assert!(e.to_string().contains("bad width"));
    }
}
