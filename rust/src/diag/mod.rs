//! DIAG: the paper's agile hardware-generator design flow (§III).
//!
//! DIAG structures a hardware generator into four layers:
//!
//! * **D**efinition — a [`spec::FunctionTree`] of functional fragments:
//!   the *basic framework* (required), *extensions* (optional) and the
//!   *parameters* extracted from mutable hardware settings.
//! * **I**mplementation — [`plugin::Plugin`]s carrying the physical
//!   description. Each plugin elaborates in three blocking stages
//!   (`create_config`, `create_early`, `create_late`) and communicates with
//!   other plugins exclusively through typed [`service::ServiceRegistry`]
//!   entries — the Function-Plugin-Service approach.
//! * **A**pplication — a [`generator::Generator`] assembled bottom-up from
//!   plugins ("plugin everything"); unplugging a plugin re-binds service
//!   consumers to the remaining providers (Fig. 3's `A→B→C ⇒ A→C`) and
//!   leaves **zero residual logic** in the generated netlist.
//! * **G**eneration — the elaborated artifact: a structural netlist
//!   (emitted as Verilog by [`crate::netlist`]), a machine description for
//!   the cycle-accurate simulator, and an elaboration trace used by the
//!   Fig. 6d productivity experiments.
//!
//! The framework is target-agnostic (the paper argues DIAG applies to any
//! generator); the WindMill CGRA instantiates it in [`crate::plugins`].

pub mod error;
pub mod generator;
pub mod handle;
pub mod plugin;
pub mod service;
pub mod spec;

pub use error::DiagError;
pub use generator::{Elaborated, Generator, StageTrace};
pub use handle::Handle;
pub use plugin::{ElabCtx, Plugin, Stage, Target};
pub use service::ServiceRegistry;
pub use spec::{FunctionKind, FunctionTree};
