//! `Handle<T>` — SpinalHDL-style lazily-bound elaboration values.
//!
//! In the Definition layer every leaf of the function tree is *declared*
//! before any hardware type exists; the Implementation layer's
//! `create_early` stage later *loads* the concrete value, and `create_late`
//! consumers read it (paper §IV-B: "leaves are initialized as Handle[Data]
//! waiting for declaring required hardware types through create-early").
//! Only loaded handles produce hardware — unloaded branches vanish with no
//! residue.

use std::cell::{Ref, RefCell};
use std::rc::Rc;

use super::error::DiagError;

/// A named, lazily-loaded, shared elaboration value.
#[derive(Debug)]
pub struct Handle<T> {
    name: String,
    slot: Rc<RefCell<Option<T>>>,
}

impl<T> Clone for Handle<T> {
    fn clone(&self) -> Self {
        Handle { name: self.name.clone(), slot: Rc::clone(&self.slot) }
    }
}

impl<T> Handle<T> {
    pub fn new(name: impl Into<String>) -> Self {
        Handle { name: name.into(), slot: Rc::new(RefCell::new(None)) }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Load the value; loading twice is a plugin bug and panics.
    pub fn load(&self, value: T) {
        let mut slot = self.slot.borrow_mut();
        assert!(slot.is_none(), "handle `{}` loaded twice", self.name);
        *slot = Some(value);
    }

    /// Replace the value regardless of load state (used by calibration
    /// feedback from the Generation layer back into Definition).
    pub fn reload(&self, value: T) {
        *self.slot.borrow_mut() = Some(value);
    }

    pub fn is_loaded(&self) -> bool {
        self.slot.borrow().is_some()
    }

    /// Borrow the loaded value, or a `DiagError::UnloadedHandle`.
    pub fn try_get(&self) -> Result<Ref<'_, T>, DiagError> {
        let r = self.slot.borrow();
        if r.is_none() {
            return Err(DiagError::UnloadedHandle(self.name.clone()));
        }
        Ok(Ref::map(r, |o| o.as_ref().unwrap()))
    }

    /// Borrow the loaded value; panics with the handle name if unloaded.
    pub fn get(&self) -> Ref<'_, T> {
        self.try_get()
            .unwrap_or_else(|e| panic!("{e}"))
    }
}

impl<T: Clone> Handle<T> {
    pub fn cloned(&self) -> Result<T, DiagError> {
        Ok(self.try_get()?.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_then_get() {
        let h: Handle<u32> = Handle::new("pe.width");
        assert!(!h.is_loaded());
        h.load(32);
        assert!(h.is_loaded());
        assert_eq!(*h.get(), 32);
    }

    #[test]
    fn clones_share_the_slot() {
        let h: Handle<String> = Handle::new("bus");
        let h2 = h.clone();
        h.load("axi".into());
        assert_eq!(&*h2.get(), "axi");
    }

    #[test]
    fn unloaded_get_is_error() {
        let h: Handle<u8> = Handle::new("ghost");
        let err = h.try_get().err().unwrap();
        assert!(err.to_string().contains("ghost"));
    }

    #[test]
    #[should_panic(expected = "loaded twice")]
    fn double_load_panics() {
        let h: Handle<u8> = Handle::new("x");
        h.load(1);
        h.load(2);
    }

    #[test]
    fn reload_overrides() {
        let h: Handle<u8> = Handle::new("cal");
        h.load(1);
        h.reload(9);
        assert_eq!(*h.get(), 9);
    }
}
