//! Chrome `trace_event` export of a profiled design-space sweep.
//!
//! [`chrome_trace`] renders a [`SweepReport`] as the JSON the Chrome
//! tracing UI and Perfetto ingest (`chrome://tracing` → Load, or
//! ui.perfetto.dev): an object with a `traceEvents` array of `"X"`
//! complete-spans, `"C"` counters and `"M"` metadata records.
//!
//! Two virtual processes:
//!
//! - **pid 1 — sweep pipeline.** One thread per evaluated grid point
//!   (tid = point index, thread name = point label) carrying the point's
//!   per-stage wall time from [`JobTiming`] as back-to-back `"X"` spans
//!   (`elaborate` → `compile` → `simulate` → `baseline`). Timestamps are
//!   real microseconds (`ns / 1000`).
//! - **pid 2 — PE / smem activity.** The *focus point* — the first Pareto
//!   frontier member with a sampled activity timeline, falling back to any
//!   profiled point — contributes one `"C"` counter track per PE row
//!   (`pe-row-R`, fires per sampling window) and per shared-memory bank
//!   (`smem-bank-B`, conflict cycles per window). Here the time axis is
//!   *virtual*: 1 simulated cycle = 1 µs, so the Perfetto ruler reads
//!   directly in cycles. A profiled point without a timeline (stride 0)
//!   still emits one aggregate counter sample per row/bank so the tracks
//!   exist.
//!
//! The emitter only uses [`crate::util::json::Json`], so the output is
//! valid JSON by construction — `benches/telemetry_overhead.rs` re-parses
//! it and checks the per-row tracks.

use crate::coordinator::{JobTiming, SweepPoint, SweepReport};
use crate::sim::TelemetrySummary;
use crate::util::json::Json;

/// Virtual pid of the per-point pipeline-stage spans.
const PID_PIPELINE: usize = 1;
/// Virtual pid of the focus point's PE/smem activity counters.
const PID_ACTIVITY: usize = 2;

/// Render `report` as a complete Chrome `trace_event` JSON document.
pub fn chrome_trace(report: &SweepReport) -> String {
    let mut events: Vec<Json> = vec![
        meta_event(PID_PIPELINE, 0, "process_name", "windmill sweep pipeline"),
        meta_event(PID_ACTIVITY, 0, "process_name", "windmill pe/smem activity"),
    ];
    for (i, p) in report.points.iter().enumerate() {
        events.push(meta_event(PID_PIPELINE, i, "thread_name", &p.label));
        push_stage_spans(&mut events, i, &p.label, &p.timing);
    }
    if let Some(p) = focus_point(report) {
        events.push(meta_event(PID_ACTIVITY, 0, "thread_name", &p.label));
        if let Some(t) = &p.telemetry {
            push_activity_counters(&mut events, t);
        }
    }
    let events = Json::Arr(events);
    Json::obj(vec![("traceEvents", events), ("displayTimeUnit", "ms".into())]).to_string()
}

/// The point whose activity pid 2 shows: the first frontier member with a
/// sampled timeline, else the first profiled frontier member, else the
/// first profiled point anywhere. `None` on unprofiled sweeps — the trace
/// then carries pipeline spans only.
fn focus_point(report: &SweepReport) -> Option<&SweepPoint> {
    let frontier = report.frontier_points();
    frontier
        .iter()
        .find(|p| p.telemetry.as_ref().is_some_and(|t| !t.timeline.is_empty()))
        .copied()
        .or_else(|| frontier.into_iter().find(|p| p.telemetry.is_some()))
        .or_else(|| report.points.iter().find(|p| p.telemetry.is_some()))
}

fn meta_event(pid: usize, tid: usize, which: &str, name: &str) -> Json {
    Json::obj(vec![
        ("name", which.into()),
        ("ph", "M".into()),
        ("pid", pid.into()),
        ("tid", tid.into()),
        ("args", Json::obj(vec![("name", name.into())])),
    ])
}

/// The point's pipeline stages as consecutive `"X"` spans on its own tid.
/// `JobTiming` records durations, not wall timestamps, so the spans are
/// laid out back-to-back from t=0 — relative widths are what the view is
/// for. Zero-length stages (fully cached) are skipped.
fn push_stage_spans(events: &mut Vec<Json>, tid: usize, label: &str, t: &JobTiming) {
    let stages = [
        ("elaborate", t.elaborate_ns),
        ("compile", t.compile_ns),
        ("simulate", t.simulate_ns),
        ("baseline", t.baseline_ns),
    ];
    let mut cursor_ns = 0u64;
    for (name, dur_ns) in stages {
        if dur_ns > 0 {
            events.push(Json::obj(vec![
                ("name", name.into()),
                ("cat", "sweep".into()),
                ("ph", "X".into()),
                ("ts", (cursor_ns as f64 / 1e3).into()),
                ("dur", (dur_ns as f64 / 1e3).into()),
                ("pid", PID_PIPELINE.into()),
                ("tid", tid.into()),
                ("args", Json::obj(vec![("point", label.into())])),
            ]));
        }
        cursor_ns += dur_ns;
    }
}

fn counter_event(name: String, ts_us: f64, series: &str, value: u64) -> Json {
    Json::obj(vec![
        ("name", Json::Str(name)),
        ("ph", "C".into()),
        ("ts", ts_us.into()),
        ("pid", PID_ACTIVITY.into()),
        ("args", Json::obj(vec![(series, (value as usize).into())])),
    ])
}

/// Counter samples for the focus point: one `pe-row-R` / `smem-bank-B`
/// value per timeline window at the window's start cycle (1 cycle = 1 µs),
/// plus a zero sample closing each track at the end of the run. Without a
/// timeline, a single aggregate sample per row/bank at t=0.
fn push_activity_counters(events: &mut Vec<Json>, t: &TelemetrySummary) {
    if t.timeline.is_empty() {
        let rows = t.pe.iter().map(|a| a.row as usize + 1).max().unwrap_or(0);
        for r in 0..rows {
            let fires: u64 = t.pe.iter().filter(|a| a.row as usize == r).map(|a| a.fires).sum();
            events.push(counter_event(format!("pe-row-{r}"), 0.0, "fires", fires));
        }
        for (b, &c) in t.bank_conflicts.iter().enumerate() {
            events.push(counter_event(format!("smem-bank-{b}"), 0.0, "conflicts", c));
        }
        return;
    }
    let mut end = 0u64;
    for span in &t.timeline {
        let ts = span.start as f64;
        for (r, &fires) in span.rows_fired.iter().enumerate() {
            events.push(counter_event(format!("pe-row-{r}"), ts, "fires", fires as u64));
        }
        for (b, &c) in span.bank_conflicts.iter().enumerate() {
            events.push(counter_event(format!("smem-bank-{b}"), ts, "conflicts", c as u64));
        }
        end = end.max(span.start + span.dur);
    }
    if let Some(last) = t.timeline.last() {
        for r in 0..last.rows_fired.len() {
            events.push(counter_event(format!("pe-row-{r}"), end as f64, "fires", 0));
        }
        for b in 0..last.bank_conflicts.len() {
            events.push(counter_event(format!("smem-bank-{b}"), end as f64, "conflicts", 0));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{SweepAccumulator, WorkloadPerf};
    use crate::sim::TimelineSpan;

    fn point(label: &str, area: f64, power: f64, time: f64) -> SweepPoint {
        SweepPoint {
            label: label.to_string(),
            arch_hash: 1,
            pea: "4x4".into(),
            topology: "mesh2d",
            gates: 1.0,
            area_mm2: area,
            power_mw: power,
            fmax_mhz: 750.0,
            cycles: time as u64,
            wm_time_ns: time,
            speedup_vs_cpu: 1.0,
            speedup_vs_gpu: 1.0,
            ii: 1,
            bound: 0,
            per_workload: vec![WorkloadPerf {
                workload: "wl".into(),
                cycles: time as u64,
                wm_time_ns: time,
                speedup_vs_cpu: 1.0,
                speedup_vs_gpu: 1.0,
                ii: 1,
                bound: 0,
            }],
            timing: JobTiming {
                elaborate_ns: 2_000,
                compile_ns: 3_000,
                simulate_ns: 5_000,
                baseline_ns: 0, // cached: no span emitted
                ..Default::default()
            },
            telemetry: None,
        }
    }

    fn timeline_telemetry() -> TelemetrySummary {
        TelemetrySummary {
            sim_cycles: 64,
            fires: 20,
            sample_stride: 32,
            bank_conflicts: vec![1, 5],
            timeline: vec![
                TimelineSpan {
                    start: 0,
                    dur: 32,
                    rows_fired: vec![12, 8],
                    bank_conflicts: vec![1, 3],
                },
                TimelineSpan {
                    start: 32,
                    dur: 32,
                    rows_fired: vec![0, 0],
                    bank_conflicts: vec![0, 2],
                },
            ],
            ..Default::default()
        }
    }

    fn events(doc: &str) -> Vec<Json> {
        let j = Json::parse(doc).expect("trace must be valid JSON");
        j.get("traceEvents").unwrap().as_arr().unwrap().to_vec()
    }

    fn named<'a>(evs: &'a [Json], name: &str) -> Vec<&'a Json> {
        evs.iter().filter(|e| e.get("name").and_then(Json::as_str) == Some(name)).collect()
    }

    #[test]
    fn profiled_report_exports_spans_and_per_row_counters() {
        let mut acc = SweepAccumulator::new();
        let mut hot = point("hot", 1.0, 1.0, 10.0);
        hot.telemetry = Some(timeline_telemetry());
        acc.push(hot);
        acc.push(point("cold", 2.0, 2.0, 20.0));
        let r = acc.finish(Default::default(), 1);

        let evs = events(&chrome_trace(&r));
        // Pipeline spans: 3 nonzero stages per point, zero-length skipped.
        assert_eq!(named(&evs, "simulate").len(), 2);
        assert!(named(&evs, "baseline").is_empty());
        let sim = named(&evs, "simulate")[0];
        assert_eq!(sim.get("ph").unwrap().as_str(), Some("X"));
        // elaborate (2 µs) + compile (3 µs) precede simulate on the tid.
        assert_eq!(sim.get("ts").unwrap().as_f64(), Some(5.0));
        assert_eq!(sim.get("dur").unwrap().as_f64(), Some(5.0));

        // Activity counters: every PE row and bank has a track, sampled at
        // each window start plus the closing zero.
        for name in ["pe-row-0", "pe-row-1", "smem-bank-0", "smem-bank-1"] {
            assert_eq!(named(&evs, name).len(), 3, "{name}");
        }
        let row0 = named(&evs, "pe-row-0");
        assert_eq!(row0[0].get("ts").unwrap().as_f64(), Some(0.0));
        assert_eq!(row0[0].at(&["args", "fires"]).unwrap().as_usize(), Some(12));
        assert_eq!(row0[1].get("ts").unwrap().as_f64(), Some(32.0));
        assert_eq!(row0[2].get("ts").unwrap().as_f64(), Some(64.0));
        // The focus point is named on pid 2.
        let threads = named(&evs, "thread_name");
        let focus_named = threads.iter().any(|e| {
            e.get("pid").unwrap().as_usize() == Some(super::PID_ACTIVITY)
                && e.at(&["args", "name"]).unwrap().as_str() == Some("hot")
        });
        assert!(focus_named);
    }

    #[test]
    fn unprofiled_report_still_yields_valid_pipeline_trace() {
        let mut acc = SweepAccumulator::new();
        acc.push(point("only", 1.0, 1.0, 10.0));
        let r = acc.finish(Default::default(), 1);
        let evs = events(&chrome_trace(&r));
        assert_eq!(named(&evs, "simulate").len(), 1);
        assert!(evs.iter().all(|e| e.get("ph").unwrap().as_str() != Some("C")));
    }

    #[test]
    fn timeline_less_telemetry_gets_aggregate_counter_samples() {
        use crate::sim::PeActivity;
        let mut acc = SweepAccumulator::new();
        let mut p = point("agg", 1.0, 1.0, 10.0);
        p.telemetry = Some(TelemetrySummary {
            sim_cycles: 100,
            fires: 9,
            pe: vec![
                PeActivity { row: 0, col: 0, fires: 4, stalls: 1 },
                PeActivity { row: 0, col: 1, fires: 3, stalls: 2 },
                PeActivity { row: 2, col: 0, fires: 2, stalls: 3 },
            ],
            bank_conflicts: vec![7],
            ..Default::default()
        });
        acc.push(p);
        let r = acc.finish(Default::default(), 1);
        let evs = events(&chrome_trace(&r));
        // Rows 0..=2 each get one aggregate sample (row 1 exists but is 0).
        let row0 = named(&evs, "pe-row-0");
        assert_eq!(row0.len(), 1);
        assert_eq!(row0[0].at(&["args", "fires"]).unwrap().as_usize(), Some(7));
        assert_eq!(named(&evs, "pe-row-1").len(), 1);
        assert_eq!(named(&evs, "pe-row-2").len(), 1);
        assert_eq!(
            named(&evs, "smem-bank-0")[0].at(&["args", "conflicts"]).unwrap().as_usize(),
            Some(7)
        );
    }
}
