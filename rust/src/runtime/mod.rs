//! PJRT runtime: load and execute the AOT'd Layer-2 artifacts.
//!
//! The build path (`make artifacts`) lowers every JAX entry point to HLO
//! *text* (`artifacts/<name>.hlo.txt` + `manifest.json`); this module loads
//! them through the `xla` crate (`PjRtClient::cpu` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`) so the Rust
//! coordinator can run the Pallas-backed compute graphs with **no Python on
//! the request path**. Executables are compiled once and cached.
//!
//! Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits 64-bit
//! instruction ids that the bundled xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py and DESIGN.md).
//!
//! The `xla` crate is only present on images that vendor it, so the real
//! client lives behind the `pjrt` cargo feature. Without the feature the
//! same `Runtime` API exists as a stub whose `load` fails with a clear
//! message — callers (examples, integration tests) degrade gracefully and
//! the default build stays dependency-free.

pub mod manifest;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

#[cfg(feature = "pjrt")]
mod pjrt_client {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::time::Instant;

    use super::Manifest;
    use crate::Result;

    fn err(msg: impl Into<String>) -> crate::Error {
        crate::Error::from(msg.into())
    }

    /// A loaded PJRT runtime with an executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        pub manifest: Manifest,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Runtime {
        /// Create a CPU PJRT client and read the artifact manifest.
        pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Self> {
            let dir = artifact_dir.as_ref().to_path_buf();
            let manifest = Manifest::read(dir.join("manifest.json"))
                .map_err(|e| err(format!("reading manifest in {}: {e}", dir.display())))?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| err(format!("PJRT cpu client: {e}")))?;
            Ok(Runtime { client, dir, manifest, executables: HashMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) the named artifact.
        pub fn prepare(&mut self, name: &str) -> Result<()> {
            if self.executables.contains_key(name) {
                return Ok(());
            }
            let spec = self
                .manifest
                .entry(name)
                .ok_or_else(|| err(format!("no artifact `{name}` in manifest")))?;
            let path = self.dir.join(&spec.file);
            let path_str = path
                .to_str()
                .ok_or_else(|| err(format!("non-utf8 path {}", path.display())))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| err(format!("parsing {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| err(format!("compiling {name}: {e}")))?;
            self.executables.insert(name.to_string(), exe);
            Ok(())
        }

        /// Execute an artifact on f32 inputs; returns the f32 outputs.
        ///
        /// Inputs must match the manifest's shapes (flattened row-major).
        pub fn execute(&mut self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            self.prepare(name)?;
            let spec = self.manifest.entry(name).unwrap().clone();
            if inputs.len() != spec.inputs.len() {
                return Err(err(format!(
                    "`{name}` expects {} inputs, got {}",
                    spec.inputs.len(),
                    inputs.len()
                )));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (i, (data, tspec)) in inputs.iter().zip(&spec.inputs).enumerate() {
                let want: usize = tspec.shape.iter().product::<usize>().max(1);
                if data.len() != want {
                    return Err(err(format!(
                        "`{name}` input {i}: {} elements for shape {:?}",
                        data.len(),
                        tspec.shape
                    )));
                }
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = tspec.shape.iter().map(|&d| d as i64).collect();
                let lit = lit
                    .reshape(&dims)
                    .map_err(|e| err(format!("reshape input {i} of `{name}`: {e}")))?;
                literals.push(lit);
            }
            let exe = self.executables.get(name).unwrap();
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| err(format!("executing `{name}`: {e}")))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| err(format!("fetch result of `{name}`: {e}")))?;
            // aot.py lowers with return_tuple=True: always a tuple.
            let parts = tuple.to_tuple().map_err(|e| err(format!("untuple `{name}`: {e}")))?;
            if parts.len() != spec.outputs.len() {
                return Err(err(format!(
                    "`{name}` returned {} outputs, manifest says {}",
                    parts.len(),
                    spec.outputs.len()
                )));
            }
            parts
                .into_iter()
                .enumerate()
                .map(|(i, p)| {
                    p.to_vec::<f32>().map_err(|e| err(format!("output {i} of `{name}`: {e}")))
                })
                .collect()
        }

        /// Execute and measure wall-clock time (compile excluded; the first
        /// call per artifact warms the cache).
        pub fn execute_timed(
            &mut self,
            name: &str,
            inputs: &[Vec<f32>],
        ) -> Result<(Vec<Vec<f32>>, f64)> {
            self.prepare(name)?;
            let t0 = Instant::now();
            let out = self.execute(name, inputs)?;
            Ok((out, t0.elapsed().as_nanos() as f64))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_client {
    use std::path::Path;

    use super::Manifest;
    use crate::Result;

    const DISABLED: &str = "windmill was built without the `pjrt` feature; \
         the PJRT runtime needs the vendored `xla` crate (enable with \
         `--features pjrt` on an image that carries it)";

    /// Stub runtime: the API of the PJRT client without the `xla` crate.
    /// `load` always fails, so feature-gated callers degrade at run time
    /// with an actionable message instead of failing to link.
    pub struct Runtime {
        pub manifest: Manifest,
    }

    impl Runtime {
        pub fn load(_artifact_dir: impl AsRef<Path>) -> Result<Self> {
            Err(crate::Error::from(DISABLED.to_string()))
        }

        pub fn platform(&self) -> String {
            "stub (pjrt feature disabled)".to_string()
        }

        pub fn prepare(&mut self, _name: &str) -> Result<()> {
            Err(crate::Error::from(DISABLED.to_string()))
        }

        pub fn execute(&mut self, _name: &str, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Err(crate::Error::from(DISABLED.to_string()))
        }

        pub fn execute_timed(
            &mut self,
            _name: &str,
            _inputs: &[Vec<f32>],
        ) -> Result<(Vec<Vec<f32>>, f64)> {
            Err(crate::Error::from(DISABLED.to_string()))
        }
    }
}

pub use pjrt_client::Runtime;

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<Runtime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    #[test]
    fn loads_manifest_and_platform() {
        let Some(rt) = runtime() else { return };
        assert!(!rt.platform().is_empty());
        assert!(rt.manifest.entry("gemm").is_some());
    }

    #[test]
    fn gemm_executes_and_matches_cpu_math() {
        let Some(mut rt) = runtime() else { return };
        let spec = rt.manifest.entry("gemm").unwrap().clone();
        let (m, k) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
        let n = spec.inputs[1].shape[1];
        let x: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 * 0.25).collect();
        let w: Vec<f32> = (0..k * n).map(|i| (i % 5) as f32 - 2.0).collect();
        let b: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
        let out = rt.execute("gemm", &[x.clone(), w.clone(), b.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), m * n);
        // Spot-check a few entries against naive math.
        for &(mm, nn) in &[(0usize, 0usize), (1, 2), (m - 1, n - 1)] {
            let mut want = b[nn];
            for kk in 0..k {
                want += x[mm * k + kk] * w[kk * n + nn];
            }
            let got = out[0][mm * n + nn];
            assert!((got - want).abs() < 1e-2, "C[{mm},{nn}] {got} vs {want}");
        }
    }

    #[test]
    fn wrong_arity_is_error() {
        let Some(mut rt) = runtime() else { return };
        assert!(rt.execute("gemm", &[vec![0.0; 4]]).is_err());
    }

    #[test]
    fn wrong_shape_is_error() {
        let Some(mut rt) = runtime() else { return };
        let bad = vec![vec![0.0; 3], vec![0.0; 3], vec![0.0; 3]];
        assert!(rt.execute("gemm", &bad).is_err());
    }

    #[test]
    fn unknown_artifact_is_error() {
        let Some(mut rt) = runtime() else { return };
        assert!(rt.execute("nonexistent", &[]).is_err());
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::Runtime;

    #[test]
    fn stub_load_fails_with_actionable_message() {
        let e = Runtime::load("/nonexistent").map(|_| ()).unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}
