//! `artifacts/manifest.json` parsing (shapes the AOT path recorded).

use std::path::Path;

use crate::util::Json;
use crate::Result;

fn err(msg: impl Into<String>) -> crate::Error {
    crate::Error::from(msg.into())
}

/// One tensor's shape/dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT'd entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub entries: Vec<ArtifactSpec>,
    /// Model shape constants recorded at AOT time (batch, hidden, lr…).
    pub shapes: Json,
}

impl Manifest {
    pub fn read(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| err(format!("reading {}: {e}", path.as_ref().display())))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| err(format!("manifest: {e}")))?;
        let format = j.at(&["format"]).and_then(Json::as_str).unwrap_or("");
        if format != "hlo-text/return-tuple" {
            return Err(err(format!("unsupported artifact format `{format}`")));
        }
        let entries_obj = j
            .at(&["entries"])
            .and_then(Json::as_obj)
            .ok_or_else(|| err("manifest missing `entries`"))?;
        let tensor = |t: &Json| -> Result<TensorSpec> {
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| err("tensor missing shape"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| err("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            let dtype =
                t.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string();
            Ok(TensorSpec { shape, dtype })
        };
        let mut entries = Vec::new();
        for (name, ent) in entries_obj {
            let file = ent
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| err(format!("entry `{name}` missing file")))?
                .to_string();
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
                ent.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| err(format!("entry `{name}` missing {key}")))?
                    .iter()
                    .map(tensor)
                    .collect()
            };
            entries.push(ArtifactSpec {
                name: name.clone(),
                file,
                inputs: parse_list("inputs")?,
                outputs: parse_list("outputs")?,
            });
        }
        let shapes = j.at(&["shapes"]).cloned().unwrap_or(Json::Null);
        Ok(Manifest { entries, shapes })
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactSpec> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Shape constant recorded at AOT time (e.g. "batch", "hidden").
    pub fn shape_const(&self, key: &str) -> Option<f64> {
        self.shapes.get(key).and_then(Json::as_f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text/return-tuple",
      "shapes": {"batch": 64, "hidden": 32, "lr": 0.05},
      "entries": {
        "gemm": {
          "file": "gemm.hlo.txt",
          "inputs": [
            {"shape": [64, 64], "dtype": "float32"},
            {"shape": [64, 64], "dtype": "float32"},
            {"shape": [64], "dtype": "float32"}
          ],
          "outputs": [{"shape": [64, 64], "dtype": "float32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let g = m.entry("gemm").unwrap();
        assert_eq!(g.file, "gemm.hlo.txt");
        assert_eq!(g.inputs.len(), 3);
        assert_eq!(g.inputs[0].shape, vec![64, 64]);
        assert_eq!(g.inputs[0].elements(), 4096);
        assert_eq!(g.outputs[0].shape, vec![64, 64]);
        assert_eq!(m.shape_const("batch"), Some(64.0));
        assert_eq!(m.shape_const("lr"), Some(0.05));
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text/return-tuple", "protobuf");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn scalar_tensor_has_one_element() {
        let t = TensorSpec { shape: vec![], dtype: "float32".into() };
        assert_eq!(t.elements(), 1);
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::read(path).unwrap();
            assert!(m.entry("policy_step").is_some());
            assert_eq!(m.entry("policy_step").unwrap().outputs.len(), 5);
        }
    }
}
