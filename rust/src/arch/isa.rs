//! The coarse-grained PE operation set and configuration word format.
//!
//! WindMill PEs are word-granularity (32-bit) functional units configured
//! by context-memory words rather than fetched instructions. A
//! [`ConfigWord`] is what the PE's config-flow pipeline (fetch → decode)
//! resolves each control step; the data-flow half (execute → write-back)
//! then applies [`Op::eval`] to the selected operands.
//!
//! The binary layout ([`ConfigWord::encode`] / [`ConfigWord::decode`]) is
//! 128 bits, which is also what the context-memory area/power accounting
//! uses. The special-function ops (`Tanh`…`Div`) exist only when the SFU
//! extension plugin is plugged — the mapper checks capability sets from the
//! machine description, not this enum.

use crate::diag::error::DiagError;

/// PE operations. `eval` gives the architectural (f32 word) semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Op {
    Nop = 0,
    /// Pass operand A through unchanged (routing PE).
    Route,
    Add,
    Sub,
    Mul,
    /// Multiply-accumulate: `a * b + acc` (acc is the PE's local register 0).
    Mac,
    Neg,
    Abs,
    Min,
    Max,
    /// Bitwise ops act on the IEEE-754 bit patterns of the 32-bit word.
    And,
    Or,
    Xor,
    Not,
    Shl,
    Shr,
    /// Comparisons produce 1.0 / 0.0.
    Lt,
    Le,
    Eq,
    /// Select: `if a != 0 { b } else { imm }` — with b/imm operand selects.
    Sel,
    /// LSU only: shared-memory read (address = a + imm).
    Load,
    /// LSU only: shared-memory write (address = a + imm, data = b).
    Store,
    // ---- special-function unit (extension plugin) ----
    Tanh,
    Exp,
    Log,
    Recip,
    Sqrt,
    Div,
}

/// Functional category — drives per-PE area/power accounting and
/// capability checks in the mapper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpClass {
    Control,
    Route,
    Alu,
    Mul,
    Sfu,
    Mem,
}

impl Op {
    pub const ALL: [Op; 27] = [
        Op::Nop,
        Op::Route,
        Op::Add,
        Op::Sub,
        Op::Mul,
        Op::Mac,
        Op::Neg,
        Op::Abs,
        Op::Min,
        Op::Max,
        Op::And,
        Op::Or,
        Op::Xor,
        Op::Not,
        Op::Shl,
        Op::Shr,
        Op::Lt,
        Op::Le,
        Op::Eq,
        Op::Sel,
        Op::Load,
        Op::Store,
        Op::Tanh,
        Op::Exp,
        Op::Log,
        Op::Recip,
        Op::Sqrt,
    ];

    pub fn class(self) -> OpClass {
        use Op::*;
        match self {
            Nop => OpClass::Control,
            Route => OpClass::Route,
            Add | Sub | Neg | Abs | Min | Max | And | Or | Xor | Not | Shl | Shr | Lt | Le
            | Eq | Sel => OpClass::Alu,
            Mul | Mac => OpClass::Mul,
            Tanh | Exp | Log | Recip | Sqrt | Div => OpClass::Sfu,
            Load | Store => OpClass::Mem,
        }
    }

    /// Execute-stage latency in cycles (post-decode, pre-writeback).
    pub fn latency(self) -> u32 {
        match self.class() {
            OpClass::Control | OpClass::Route => 1,
            OpClass::Alu => 1,
            OpClass::Mul => 2,
            OpClass::Sfu => 4,
            OpClass::Mem => 2, // plus bank-arbitration stalls at run time
        }
    }

    /// Architectural semantics on 32-bit words viewed as f32 (bitwise ops
    /// act on the raw bits; `acc` is PE-local register 0 for `Mac`).
    pub fn eval(self, a: f32, b: f32, acc: f32) -> f32 {
        use Op::*;
        let bits = |x: f32| x.to_bits();
        let fb = f32::from_bits;
        match self {
            Nop => 0.0,
            Route => a,
            Add => a + b,
            Sub => a - b,
            Mul => a * b,
            Mac => a * b + acc,
            Neg => -a,
            Abs => a.abs(),
            Min => a.min(b),
            Max => a.max(b),
            And => fb(bits(a) & bits(b)),
            Or => fb(bits(a) | bits(b)),
            Xor => fb(bits(a) ^ bits(b)),
            Not => fb(!bits(a)),
            Shl => fb(bits(a) << (bits(b) & 31)),
            Shr => fb(bits(a) >> (bits(b) & 31)),
            Lt => (a < b) as u32 as f32,
            Le => (a <= b) as u32 as f32,
            Eq => (a == b) as u32 as f32,
            Sel => {
                if a != 0.0 {
                    b
                } else {
                    acc
                }
            }
            Load | Store => a, // resolved by the LSU model, not here
            Tanh => a.tanh(),
            Exp => a.exp(),
            Log => a.ln(),
            Recip => 1.0 / a,
            Sqrt => a.sqrt(),
            Div => a / b,
        }
    }

    /// Decode an opcode byte (inverse of `op as u8`); `None` for values
    /// outside the ISA. Used by `ConfigWord::decode` and the artifact
    /// store's binary codec.
    pub fn from_u8(x: u8) -> Option<Op> {
        use Op::*;
        Some(match x {
            0 => Nop,
            1 => Route,
            2 => Add,
            3 => Sub,
            4 => Mul,
            5 => Mac,
            6 => Neg,
            7 => Abs,
            8 => Min,
            9 => Max,
            10 => And,
            11 => Or,
            12 => Xor,
            13 => Not,
            14 => Shl,
            15 => Shr,
            16 => Lt,
            17 => Le,
            18 => Eq,
            19 => Sel,
            20 => Load,
            21 => Store,
            22 => Tanh,
            23 => Exp,
            24 => Log,
            25 => Recip,
            26 => Sqrt,
            27 => Div,
            _ => return None,
        })
    }
}

/// Operand source select for the two PE inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    /// Nothing connected (defaults to 0).
    None,
    /// Input latch fed by neighbour port `idx` (index into the PE's sorted
    /// neighbour list — see `Topology::neighbors`).
    Port(u8),
    /// PE-local register file entry.
    Reg(u8),
    /// The config word's immediate field.
    Imm,
    /// Shared-register file entry (inter-schedule delivery).
    SharedReg(u8),
}

impl Operand {
    fn encode(self) -> u16 {
        match self {
            Operand::None => 0,
            Operand::Port(i) => 0x100 | i as u16,
            Operand::Reg(i) => 0x200 | i as u16,
            Operand::Imm => 0x300,
            Operand::SharedReg(i) => 0x400 | i as u16,
        }
    }

    fn decode(x: u16) -> Option<Operand> {
        let idx = (x & 0xFF) as u8;
        Some(match x & 0xF00 {
            0x000 => Operand::None,
            0x100 => Operand::Port(idx),
            0x200 => Operand::Reg(idx),
            0x300 => Operand::Imm,
            0x400 => Operand::SharedReg(idx),
            _ => return None,
        })
    }
}

/// Output port selector bitmask (up to 8 neighbour ports) plus local
/// register / shared register write enables.
pub type PortSel = u8;

/// One context-memory configuration word (128-bit encoded form).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConfigWord {
    pub op: Op,
    pub src_a: Operand,
    pub src_b: Operand,
    /// Broadcast result to these neighbour output ports.
    pub out_ports: PortSel,
    /// Also latch result into local register `Some(idx)`.
    pub write_reg: Option<u8>,
    /// Also write result into shared register `Some(idx)`.
    pub write_shared: Option<u8>,
    /// Immediate (used by `Operand::Imm`, `Load`/`Store` offset, `Sel`).
    pub imm: f32,
    /// Iteration-control block: repeat this configuration for `iter_count`
    /// data beats before the program counter advances (§IV-A.3).
    pub iter_count: u16,
}

impl Default for ConfigWord {
    fn default() -> Self {
        ConfigWord {
            op: Op::Nop,
            src_a: Operand::None,
            src_b: Operand::None,
            out_ports: 0,
            write_reg: None,
            write_shared: None,
            imm: 0.0,
            iter_count: 1,
        }
    }
}

impl ConfigWord {
    pub const ENCODED_BITS: u32 = 128;

    /// Pack into the 128-bit context-memory layout.
    pub fn encode(&self) -> [u32; 4] {
        let w0 = (self.op as u8 as u32)
            | ((self.src_a.encode() as u32) << 8)
            | ((self.out_ports as u32) << 24);
        let w1 = (self.src_b.encode() as u32)
            | ((self.write_reg.map_or(0u32, |r| 0x100 | r as u32)) << 12)
            | ((self.write_shared.map_or(0u32, |r| 0x100 | r as u32)) << 22);
        let w2 = self.imm.to_bits();
        let w3 = self.iter_count as u32;
        [w0, w1, w2, w3]
    }

    /// Unpack; errors on malformed fields (fuzzed by property tests).
    pub fn decode(words: [u32; 4]) -> Result<ConfigWord, DiagError> {
        let bad = |m: &str| DiagError::InvalidParams(format!("config word: {m}"));
        let op = Op::from_u8((words[0] & 0xFF) as u8).ok_or_else(|| bad("bad opcode"))?;
        let src_a = Operand::decode(((words[0] >> 8) & 0xFFF) as u16)
            .ok_or_else(|| bad("bad src_a"))?;
        let out_ports = ((words[0] >> 24) & 0xFF) as u8;
        let src_b =
            Operand::decode((words[1] & 0xFFF) as u16).ok_or_else(|| bad("bad src_b"))?;
        let wr = ((words[1] >> 12) & 0x3FF) as u32;
        let write_reg = if wr & 0x100 != 0 { Some((wr & 0xFF) as u8) } else { None };
        let ws = ((words[1] >> 22) & 0x3FF) as u32;
        let write_shared = if ws & 0x100 != 0 { Some((ws & 0xFF) as u8) } else { None };
        let imm = f32::from_bits(words[2]);
        let iter_count = (words[3] & 0xFFFF) as u16;
        Ok(ConfigWord {
            op,
            src_a,
            src_b,
            out_ports,
            write_reg,
            write_shared,
            imm,
            iter_count,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_arithmetic() {
        assert_eq!(Op::Add.eval(2.0, 3.0, 0.0), 5.0);
        assert_eq!(Op::Sub.eval(2.0, 3.0, 0.0), -1.0);
        assert_eq!(Op::Mul.eval(2.0, 3.0, 0.0), 6.0);
        assert_eq!(Op::Mac.eval(2.0, 3.0, 10.0), 16.0);
        assert_eq!(Op::Max.eval(-1.0, 4.0, 0.0), 4.0);
        assert_eq!(Op::Route.eval(7.5, 0.0, 0.0), 7.5);
    }

    #[test]
    fn eval_compare_and_select() {
        assert_eq!(Op::Lt.eval(1.0, 2.0, 0.0), 1.0);
        assert_eq!(Op::Lt.eval(2.0, 1.0, 0.0), 0.0);
        assert_eq!(Op::Sel.eval(1.0, 42.0, 7.0), 42.0);
        assert_eq!(Op::Sel.eval(0.0, 42.0, 7.0), 7.0);
    }

    #[test]
    fn eval_bitwise_on_bits() {
        let a = f32::from_bits(0xF0F0_F0F0);
        let b = f32::from_bits(0x0FF0_0FF0);
        assert_eq!(Op::And.eval(a, b, 0.0).to_bits(), 0x00F0_00F0);
        assert_eq!(Op::Xor.eval(a, b, 0.0).to_bits(), 0xFF00_FF00);
    }

    #[test]
    fn eval_sfu() {
        assert!((Op::Tanh.eval(0.5, 0.0, 0.0) - 0.5f32.tanh()).abs() < 1e-7);
        assert!((Op::Exp.eval(1.0, 0.0, 0.0) - std::f32::consts::E).abs() < 1e-6);
        assert_eq!(Op::Recip.eval(4.0, 0.0, 0.0), 0.25);
        assert_eq!(Op::Div.eval(1.0, 8.0, 0.0), 0.125);
    }

    #[test]
    fn classes_and_latencies() {
        assert_eq!(Op::Add.class(), OpClass::Alu);
        assert_eq!(Op::Mac.class(), OpClass::Mul);
        assert_eq!(Op::Tanh.class(), OpClass::Sfu);
        assert_eq!(Op::Load.class(), OpClass::Mem);
        assert!(Op::Tanh.latency() > Op::Add.latency());
    }

    #[test]
    fn config_word_roundtrip_exhaustive_ops() {
        for (i, op) in Op::ALL.into_iter().enumerate() {
            let cw = ConfigWord {
                op,
                src_a: Operand::Port((i % 8) as u8),
                src_b: if i % 2 == 0 { Operand::Imm } else { Operand::Reg(3) },
                out_ports: (i * 37 % 256) as u8,
                write_reg: if i % 3 == 0 { Some(5) } else { None },
                write_shared: if i % 4 == 0 { Some(2) } else { None },
                imm: i as f32 * -1.5,
                iter_count: (i * 991 % 65536) as u16,
            };
            let back = ConfigWord::decode(cw.encode()).unwrap();
            assert_eq!(cw, back, "op {op:?}");
        }
    }

    #[test]
    fn decode_rejects_bad_opcode() {
        let mut w = ConfigWord::default().encode();
        w[0] = (w[0] & !0xFF) | 0xFE;
        assert!(ConfigWord::decode(w).is_err());
    }

    #[test]
    fn operand_roundtrip() {
        for o in [
            Operand::None,
            Operand::Port(7),
            Operand::Reg(15),
            Operand::Imm,
            Operand::SharedReg(3),
        ] {
            assert_eq!(Operand::decode(o.encode()), Some(o));
        }
    }

    #[test]
    fn nan_imm_roundtrips_bitexact() {
        let cw = ConfigWord { imm: f32::NAN, ..Default::default() };
        let back = ConfigWord::decode(cw.encode()).unwrap();
        assert_eq!(cw.imm.to_bits(), back.imm.to_bits());
    }
}
