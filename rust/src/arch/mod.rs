//! WindMill architecture definition (paper §IV-A) — the Definition layer
//! instantiated for the CGRA target.
//!
//! * [`params`] — the typed, mutable hardware settings ("Parameter" part of
//!   the definition triple): PEA geometry, PE-type map, interconnect
//!   topology, shared-memory shape, execution mode, RCA ring size.
//! * [`isa`] — the coarse-grained PE operation set and the configuration
//!   word format decoded by the PE's config-flow pipeline.
//! * [`topology`] — 2D-mesh / 1-hop / torus interconnect descriptions used
//!   by the router, the area model and the simulator alike.
//! * [`presets`] — ready-made parameter sets, including the paper's
//!   standard WindMill (8×8 PEA: 28 boundary LSUs around 35 GPEs + 1 CPE,
//!   16 × 256 × 32-bit shared-memory banks, 4-RCA ring).

pub mod isa;
pub mod params;
pub mod presets;
pub mod topology;

pub use isa::{ConfigWord, Op, Operand, PortSel};
pub use params::{ExecMode, PeType, SharedRegMode, WindMillParams};
pub use topology::Topology;
