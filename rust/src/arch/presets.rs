//! Ready-made WindMill parameter sets (paper §IV-B: "several WindMill CGRA
//! presets are prepared").

use super::params::{ExecMode, SharedRegMode, SmemParams, WindMillParams};
use super::topology::Topology;

/// The paper's standard WindMill instance: 8×8 PEA whose boundary ring is
/// the 28 LSUs of §IV-A.4, one CPE, 2D-mesh, 16 × 256 × 32-bit shared
/// memory behind the PAI, ping-pong DMA, 4-RCA ring, 750 MHz target.
pub fn standard() -> WindMillParams {
    WindMillParams {
        rows: 8,
        cols: 8,
        data_width: 32,
        topology: Topology::Mesh2D,
        lsu_ring: true,
        cpe_enabled: true,
        sfu_enabled: true,
        context_depth: 32,
        exec_mode: ExecMode::Mcmd,
        shared_reg_mode: SharedRegMode::RowShared,
        shared_regs_per_group: 8,
        smem: SmemParams { banks: 16, depth: 256, width_bits: 32 },
        dma_width_bits: 128,
        pingpong: true,
        rca_count: 4,
        rtt_entries: 16,
        freq_mhz: 750.0,
    }
}

/// Small 4×4 instance for fast tests: ring of 12 LSUs around 3 GPEs + CPE.
pub fn small() -> WindMillParams {
    WindMillParams {
        rows: 4,
        cols: 4,
        context_depth: 16,
        smem: SmemParams { banks: 8, depth: 128, width_bits: 32 },
        rca_count: 1,
        ..standard()
    }
}

/// Large 16×16 instance for scalability sweeps.
pub fn large() -> WindMillParams {
    WindMillParams {
        rows: 16,
        cols: 16,
        smem: SmemParams { banks: 32, depth: 512, width_bits: 32 },
        ..standard()
    }
}

/// A square PEA of the given edge with otherwise-standard settings
/// (the Fig. 6a sweep generator).
pub fn with_pea_size(edge: usize) -> WindMillParams {
    WindMillParams { rows: edge, cols: edge, ..standard() }
}

/// Standard parameters with a different topology (Fig. 6c sweep).
pub fn with_topology(t: Topology) -> WindMillParams {
    WindMillParams { topology: t, ..standard() }
}

/// Standard parameters with a different shared-memory geometry.
pub fn with_smem(banks: usize, depth: usize) -> WindMillParams {
    WindMillParams {
        smem: SmemParams { banks, depth, width_bits: 32 },
        ..standard()
    }
}

/// Look up a preset by name (CLI surface).
pub fn by_name(name: &str) -> Option<WindMillParams> {
    match name {
        "standard" => Some(standard()),
        "small" => Some(small()),
        "large" => Some(large()),
        _ => None,
    }
}

pub const NAMES: [&str; 3] = ["standard", "small", "large"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_validate() {
        for name in NAMES {
            by_name(name).unwrap().validate().unwrap();
        }
    }

    #[test]
    fn sweep_generators_validate() {
        for edge in [4, 8, 12, 16] {
            with_pea_size(edge).validate().unwrap();
        }
        for t in Topology::ALL {
            with_topology(t).validate().unwrap();
        }
        with_smem(8, 128).validate().unwrap();
        with_smem(64, 1024).validate().unwrap();
    }

    #[test]
    fn small_is_smaller_than_standard() {
        assert!(small().pe_count() < standard().pe_count());
        assert!(standard().pe_count() < large().pe_count());
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(by_name("gigantic").is_none());
    }
}
