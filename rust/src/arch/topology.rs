//! PE interconnect topologies (§IV-A.2): 2D-mesh, 1-hop, torus.
//!
//! One enum serves three consumers with consistent semantics:
//! the **router** (neighbour sets for path search), the **area model**
//! (link counts), and the **simulator** (per-hop transfer latency).

/// Interconnect topology of the PEA.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    /// 4-neighbour mesh (N/E/S/W), no wraparound.
    Mesh2D,
    /// Mesh plus distance-2 express links along rows and columns.
    OneHop,
    /// Mesh with wraparound links in both dimensions.
    Torus,
}

impl Topology {
    pub const ALL: [Topology; 3] = [Topology::Mesh2D, Topology::OneHop, Topology::Torus];

    pub fn name(&self) -> &'static str {
        match self {
            Topology::Mesh2D => "mesh2d",
            Topology::OneHop => "1hop",
            Topology::Torus => "torus",
        }
    }

    pub fn parse(s: &str) -> Option<Topology> {
        match s {
            "mesh2d" | "mesh" => Some(Topology::Mesh2D),
            "1hop" | "onehop" => Some(Topology::OneHop),
            "torus" => Some(Topology::Torus),
            _ => None,
        }
    }

    /// Reachable neighbours of `(r, c)` in a `rows × cols` grid, with the
    /// hop cost of each link (express links still cost 1 cycle — that is
    /// their point; the torus wrap likewise).
    pub fn neighbors(
        &self,
        r: usize,
        c: usize,
        rows: usize,
        cols: usize,
    ) -> Vec<((usize, usize), u32)> {
        assert!(r < rows && c < cols);
        let mut out: Vec<((usize, usize), u32)> = Vec::new();
        let ri = r as isize;
        let ci = c as isize;
        let mesh: [(isize, isize); 4] = [(-1, 0), (1, 0), (0, -1), (0, 1)];
        for (dr, dc) in mesh {
            let (nr, nc) = (ri + dr, ci + dc);
            if nr >= 0 && nr < rows as isize && nc >= 0 && nc < cols as isize {
                out.push(((nr as usize, nc as usize), 1));
            }
        }
        match self {
            Topology::Mesh2D => {}
            Topology::OneHop => {
                let hop2: [(isize, isize); 4] = [(-2, 0), (2, 0), (0, -2), (0, 2)];
                for (dr, dc) in hop2 {
                    let (nr, nc) = (ri + dr, ci + dc);
                    if nr >= 0 && nr < rows as isize && nc >= 0 && nc < cols as isize {
                        out.push(((nr as usize, nc as usize), 1));
                    }
                }
            }
            Topology::Torus => {
                if rows > 2 {
                    if r == 0 {
                        out.push(((rows - 1, c), 1));
                    } else if r == rows - 1 {
                        out.push(((0, c), 1));
                    }
                }
                if cols > 2 {
                    if c == 0 {
                        out.push(((r, cols - 1), 1));
                    } else if c == cols - 1 {
                        out.push(((r, 0), 1));
                    }
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Maximum per-PE input degree (sizes the PE operand muxes — why the
    /// paper finds topology a *weak* but nonzero area effect).
    pub fn max_degree(&self) -> usize {
        match self {
            Topology::Mesh2D => 4,
            Topology::OneHop => 8,
            Topology::Torus => 4,
        }
    }

    /// Total directed link count in a `rows × cols` grid — the interconnect
    /// contribution to the area model.
    pub fn link_count(&self, rows: usize, cols: usize) -> usize {
        (0..rows)
            .flat_map(|r| (0..cols).map(move |c| (r, c)))
            .map(|(r, c)| self.neighbors(r, c, rows, cols).len())
            .sum()
    }

    /// Minimum hop distance between two PEs (BFS; small grids only).
    pub fn distance(
        &self,
        from: (usize, usize),
        to: (usize, usize),
        rows: usize,
        cols: usize,
    ) -> Option<u32> {
        if from == to {
            return Some(0);
        }
        let idx = |(r, c): (usize, usize)| r * cols + c;
        let mut dist = vec![u32::MAX; rows * cols];
        dist[idx(from)] = 0;
        let mut queue = std::collections::VecDeque::from([from]);
        while let Some(p) = queue.pop_front() {
            let d = dist[idx(p)];
            for (n, cost) in self.neighbors(p.0, p.1, rows, cols) {
                if dist[idx(n)] == u32::MAX {
                    dist[idx(n)] = d + cost;
                    if n == to {
                        return Some(d + cost);
                    }
                    queue.push_back(n);
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_corner_has_two_neighbors() {
        let n = Topology::Mesh2D.neighbors(0, 0, 4, 4);
        assert_eq!(n.len(), 2);
        assert!(n.contains(&((0, 1), 1)));
        assert!(n.contains(&((1, 0), 1)));
    }

    #[test]
    fn mesh_center_has_four() {
        assert_eq!(Topology::Mesh2D.neighbors(2, 2, 5, 5).len(), 4);
    }

    #[test]
    fn onehop_center_has_eight() {
        assert_eq!(Topology::OneHop.neighbors(2, 2, 5, 5).len(), 8);
    }

    #[test]
    fn torus_wraps_edges() {
        let n = Topology::Torus.neighbors(0, 0, 4, 4);
        assert!(n.contains(&((3, 0), 1)));
        assert!(n.contains(&((0, 3), 1)));
        assert_eq!(n.len(), 4);
    }

    #[test]
    fn torus_no_double_link_on_2xn() {
        // rows == 2: wrap would duplicate the existing mesh link.
        let n = Topology::Torus.neighbors(0, 1, 2, 4);
        let count_below = n.iter().filter(|((r, _), _)| *r == 1).count();
        assert_eq!(count_below, 1);
    }

    #[test]
    fn link_counts_ordered_by_richness() {
        let mesh = Topology::Mesh2D.link_count(8, 8);
        let onehop = Topology::OneHop.link_count(8, 8);
        let torus = Topology::Torus.link_count(8, 8);
        assert!(mesh < torus, "{mesh} vs {torus}");
        assert!(torus < onehop, "{torus} vs {onehop}");
        // Mesh 8x8: 2 * 2*8*7 directed links.
        assert_eq!(mesh, 2 * 2 * 8 * 7);
    }

    #[test]
    fn distance_mesh_is_manhattan() {
        let t = Topology::Mesh2D;
        assert_eq!(t.distance((0, 0), (3, 4), 8, 8), Some(7));
        assert_eq!(t.distance((2, 2), (2, 2), 8, 8), Some(0));
    }

    #[test]
    fn distance_onehop_shortens() {
        let d_mesh = Topology::Mesh2D.distance((0, 0), (4, 0), 8, 8).unwrap();
        let d_hop = Topology::OneHop.distance((0, 0), (4, 0), 8, 8).unwrap();
        assert_eq!(d_mesh, 4);
        assert_eq!(d_hop, 2);
    }

    #[test]
    fn distance_torus_wraps() {
        let d = Topology::Torus.distance((0, 0), (7, 0), 8, 8).unwrap();
        assert_eq!(d, 1);
    }

    #[test]
    fn parse_roundtrip() {
        for t in Topology::ALL {
            assert_eq!(Topology::parse(t.name()), Some(t));
        }
        assert_eq!(Topology::parse("hypercube"), None);
    }
}
