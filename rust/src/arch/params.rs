//! `WindMillParams` — the mutable hardware settings of the WindMill CGRA.
//!
//! Everything Fig. 6 sweeps lives here: PEA geometry, PE-type mix,
//! interconnect topology, shared-memory shape, shared-register mode,
//! execution mode and RCA ring size. Plugins read these during elaboration
//! and may adjust them in `create_config` (defaulting, legality clamps).

use super::topology::Topology;
use crate::diag::error::DiagError;
use crate::util::{Rng, StableHasher};

/// Coarse-grained PE flavour at a grid position (paper §IV-A.2/3/5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PeType {
    /// General-purpose PE: full ALU data-path.
    Gpe,
    /// Load-store unit: boundary PE with shared-memory access (affine and
    /// non-affine patterns) plus pass-through routing.
    Lsu,
    /// Controller PE: a GPE extended with RTT access that manages data and
    /// configuration migration and launch timing (§IV-A.5).
    Cpe,
}

/// Run-time execution mode (§IV-A.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Single-configuration-multiple-data: one configuration shared per PE
    /// line, freeing context memory for 8× the configurations of MCMD.
    Scmd,
    /// Multi-configuration-multiple-data: private per-PE configurations.
    Mcmd,
}

/// Shared-register data-delivery modes between schedules (§IV-A.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SharedRegMode {
    LineShared,
    RowShared,
    QuadrantShared,
    GlobalShared,
}

/// Shared-memory geometry (§IV-A.4): `banks × depth × width_bits` SRAM
/// behind the parallel access interface.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SmemParams {
    pub banks: usize,
    pub depth: usize,
    pub width_bits: u32,
}

impl SmemParams {
    pub fn total_bits(&self) -> u64 {
        self.banks as u64 * self.depth as u64 * self.width_bits as u64
    }

    pub fn words(&self) -> usize {
        self.banks * self.depth
    }
}

/// The full parameter set of one WindMill instance.
#[derive(Debug, Clone, PartialEq)]
pub struct WindMillParams {
    /// PEA rows (including the LSU boundary ring when `lsu_ring`).
    pub rows: usize,
    /// PEA columns.
    pub cols: usize,
    /// Data-path width in bits (the paper's WindMill is 32-bit).
    pub data_width: u32,
    /// Interconnect topology between PEs.
    pub topology: Topology,
    /// Boundary ring of LSUs around inner GPEs (standard WindMill).
    pub lsu_ring: bool,
    /// Replace one inner GPE with the controller PE (§IV-A.5).
    pub cpe_enabled: bool,
    /// Include the special-function unit (tanh/exp/log/div) in GPEs —
    /// an extension plugin; required by the RL workload.
    pub sfu_enabled: bool,
    /// Context-memory depth: configuration words per PE (MCMD mode).
    pub context_depth: usize,
    /// Execution mode.
    pub exec_mode: ExecMode,
    /// Shared-register delivery mode.
    pub shared_reg_mode: SharedRegMode,
    /// Shared registers per sharing group.
    pub shared_regs_per_group: usize,
    /// Shared memory geometry.
    pub smem: SmemParams,
    /// DMA bus width in bits (external storage <-> shared memory).
    pub dma_width_bits: u32,
    /// Ping-pong double buffering in shared memory (§IV-A.4 extension).
    pub pingpong: bool,
    /// Number of RCAs on the ring (§IV-A.1; standard is 4).
    pub rca_count: usize,
    /// Host register-transformation-table entries.
    pub rtt_entries: usize,
    /// Target clock frequency in MHz (the paper's instance: 750 MHz).
    pub freq_mhz: f64,
}

impl WindMillParams {
    /// PE type at grid position `(r, c)` under the current parameters.
    pub fn pe_type_at(&self, r: usize, c: usize) -> PeType {
        assert!(r < self.rows && c < self.cols, "({r},{c}) outside PEA");
        let boundary =
            r == 0 || c == 0 || r == self.rows - 1 || c == self.cols - 1;
        if self.lsu_ring && boundary {
            return PeType::Lsu;
        }
        if self.cpe_enabled && (r, c) == self.cpe_position() {
            return PeType::Cpe;
        }
        PeType::Gpe
    }

    /// The CPE sits at the first inner position (top-left inner corner)
    /// when enabled, or at (0,0) for ringless arrays.
    pub fn cpe_position(&self) -> (usize, usize) {
        if self.lsu_ring && self.rows > 2 && self.cols > 2 {
            (1, 1)
        } else {
            (0, 0)
        }
    }

    pub fn pe_count(&self) -> usize {
        self.rows * self.cols
    }

    pub fn count_of(&self, ty: PeType) -> usize {
        (0..self.rows)
            .flat_map(|r| (0..self.cols).map(move |c| (r, c)))
            .filter(|&(r, c)| self.pe_type_at(r, c) == ty)
            .count()
    }

    /// Configurations a PE can hold under the execution mode: SCMD shares
    /// one configuration per line so the same context memory holds 8× more
    /// (paper §IV-A.3).
    pub fn effective_context_depth(&self) -> usize {
        match self.exec_mode {
            ExecMode::Mcmd => self.context_depth,
            ExecMode::Scmd => self.context_depth * 8,
        }
    }

    /// Structural legality checks (plugins call this in `create_config`).
    pub fn validate(&self) -> Result<(), DiagError> {
        let err = |m: String| Err(DiagError::InvalidParams(m));
        if self.rows < 2 || self.cols < 2 {
            return err(format!("PEA {}x{} too small (min 2x2)", self.rows, self.cols));
        }
        if self.lsu_ring && (self.rows < 3 || self.cols < 3) {
            return err(format!(
                "LSU ring needs at least 3x3 (got {}x{})",
                self.rows, self.cols
            ));
        }
        if self.smem.banks == 0 || !self.smem.banks.is_power_of_two() {
            return err(format!("smem banks {} must be a nonzero power of two", self.smem.banks));
        }
        if self.smem.depth == 0 {
            return err("smem depth must be nonzero".into());
        }
        if !matches!(self.data_width, 8 | 16 | 32 | 64) {
            return err(format!("unsupported data width {}", self.data_width));
        }
        if self.context_depth == 0 {
            return err("context depth must be nonzero".into());
        }
        if self.rca_count == 0 {
            return err("need at least one RCA".into());
        }
        if self.freq_mhz <= 0.0 {
            return err(format!("bad frequency {}", self.freq_mhz));
        }
        Ok(())
    }

    /// Number of LSUs with shared-memory ports (PAI requester count).
    pub fn lsu_count(&self) -> usize {
        self.count_of(PeType::Lsu)
    }

    /// Stable content hash of the full parameter set.
    ///
    /// This is the `ArchParams` half of the coordinator's artifact-cache
    /// key (`crate::coordinator::cache`): two parameter sets hash equal iff
    /// every field is equal, and the digest is reproducible across runs and
    /// threads (FNV-1a over an explicit field encoding, not `DefaultHasher`).
    pub fn stable_hash(&self) -> u64 {
        let mut h = StableHasher::new();
        h.usize(self.rows)
            .usize(self.cols)
            .u32(self.data_width)
            .u8(self.topology as u8)
            .bool(self.lsu_ring)
            .bool(self.cpe_enabled)
            .bool(self.sfu_enabled)
            .usize(self.context_depth)
            .u8(self.exec_mode as u8)
            .u8(self.shared_reg_mode as u8)
            .usize(self.shared_regs_per_group)
            .usize(self.smem.banks)
            .usize(self.smem.depth)
            .u32(self.smem.width_bits)
            .u32(self.dma_width_bits)
            .bool(self.pingpong)
            .usize(self.rca_count)
            .usize(self.rtt_entries)
            .f64_bits(self.freq_mhz);
        h.finish()
    }

    /// Stable sub-hash of the parameters the mapper's **place and route**
    /// stages observe: PEA geometry, interconnect topology, data width and
    /// the PE-type mix (LSU ring / CPE / SFU — these decide every PE's
    /// capability set and port list in the elaborated machine). Parameters
    /// that only affect scheduling or simulation — context depth, execution
    /// mode, shared-memory geometry, shared registers, DMA, clocking — are
    /// deliberately excluded, so two sweep points that differ only in those
    /// dimensions share one `topology_hash` and therefore share cached
    /// `Place`/`Route` artifacts (`crate::coordinator::cache`), in memory
    /// and on disk. Domain-tagged so the digest can never collide with
    /// [`WindMillParams::stable_hash`] or [`WindMillParams::schedule_hash`].
    pub fn topology_hash(&self) -> u64 {
        let mut h = StableHasher::new();
        h.u8(0xF1) // domain tag: fabric (place/route) sub-hash
            .usize(self.rows)
            .usize(self.cols)
            .u32(self.data_width)
            .u8(self.topology as u8)
            .bool(self.lsu_ring)
            .bool(self.cpe_enabled)
            .bool(self.sfu_enabled);
        h.finish()
    }

    /// Stable sub-hash of the parameters only the **schedule** stage and
    /// the simulator observe: context depth and execution mode (context
    /// capacity, SCMD legality), shared-memory geometry (bank-pressure II),
    /// shared registers, DMA, RCA ring, host RTT and clocking. Together
    /// with [`WindMillParams::topology_hash`] this covers every field of
    /// [`WindMillParams::stable_hash`] — two parameter sets are equal iff
    /// both sub-hash inputs are (asserted in tests).
    pub fn schedule_hash(&self) -> u64 {
        let mut h = StableHasher::new();
        h.u8(0xF2) // domain tag: schedule-only sub-hash
            .usize(self.context_depth)
            .u8(self.exec_mode as u8)
            .u8(self.shared_reg_mode as u8)
            .usize(self.shared_regs_per_group)
            .usize(self.smem.banks)
            .usize(self.smem.depth)
            .u32(self.smem.width_bits)
            .u32(self.dma_width_bits)
            .bool(self.pingpong)
            .usize(self.rca_count)
            .usize(self.rtt_entries)
            .f64_bits(self.freq_mhz);
        h.finish()
    }

    /// Every validity-preserving single-step mutation of this parameter
    /// set along the axes the adaptive DSE drivers explore: PEA edge ±1
    /// (rows and cols step together, keeping square arrays), context
    /// depth ×2/÷2, shared-memory banks ×2/÷2 (stays a power of two) and
    /// depth ×2/÷2, every alternative topology, and the ping-pong toggle.
    /// Candidates failing [`WindMillParams::validate`] or hashing equal to
    /// `self` are dropped. The order is deterministic, so evolutionary
    /// search is reproducible without any randomness at all.
    pub fn mutations(&self) -> Vec<WindMillParams> {
        let mut cands: Vec<WindMillParams> = Vec::new();
        if self.rows > 1 && self.cols > 1 {
            let mut p = self.clone();
            p.rows -= 1;
            p.cols -= 1;
            cands.push(p);
        }
        {
            let mut p = self.clone();
            p.rows += 1;
            p.cols += 1;
            cands.push(p);
        }
        {
            let mut p = self.clone();
            p.context_depth *= 2;
            cands.push(p);
        }
        if self.context_depth >= 2 {
            let mut p = self.clone();
            p.context_depth /= 2;
            cands.push(p);
        }
        {
            let mut p = self.clone();
            p.smem.banks *= 2;
            cands.push(p);
        }
        if self.smem.banks >= 2 {
            let mut p = self.clone();
            p.smem.banks /= 2;
            cands.push(p);
        }
        {
            let mut p = self.clone();
            p.smem.depth *= 2;
            cands.push(p);
        }
        if self.smem.depth >= 2 {
            let mut p = self.clone();
            p.smem.depth /= 2;
            cands.push(p);
        }
        for t in Topology::ALL {
            if t != self.topology {
                let mut p = self.clone();
                p.topology = t;
                cands.push(p);
            }
        }
        {
            let mut p = self.clone();
            p.pingpong = !p.pingpong;
            cands.push(p);
        }
        let this = self.stable_hash();
        cands.retain(|p| p.validate().is_ok() && p.stable_hash() != this);
        cands
    }

    /// One uniformly-drawn candidate from [`WindMillParams::mutations`],
    /// or `None` when no valid single-step mutation exists. Deterministic
    /// for a fixed `rng` state — the evolutionary driver's exploration
    /// primitive.
    pub fn mutated(&self, rng: &mut Rng) -> Option<WindMillParams> {
        let cands = self.mutations();
        if cands.is_empty() {
            None
        } else {
            let i = rng.range(0, cands.len());
            Some(cands[i].clone())
        }
    }
}

// ---------------------------------------------------------------------------
// Design-space grids
// ---------------------------------------------------------------------------

/// A cartesian design-space grid over the Fig. 6 sweep axes.
///
/// Every axis left empty pins the corresponding field to the `base` value,
/// so a grid is built by naming only the dimensions under study:
///
/// ```
/// use windmill::arch::params::ParamGrid;
/// use windmill::arch::{presets, Topology};
///
/// let grid = ParamGrid::new(presets::standard())
///     .pea_edges(&[4, 8, 16])
///     .topologies(&Topology::ALL);
/// assert_eq!(grid.len(), 9);
/// ```
///
/// [`ParamGrid::points`] yields `(label, params)` pairs; points that fail
/// [`WindMillParams::validate`] are skipped (e.g. a 2×2 edge under an LSU
/// ring), so sweeps never abort on an illegal corner of the grid.
#[derive(Debug, Clone)]
pub struct ParamGrid {
    base: WindMillParams,
    pea_edges: Vec<usize>,
    topologies: Vec<Topology>,
    smem_geoms: Vec<(usize, usize)>,
    sfu: Vec<bool>,
    cpe: Vec<bool>,
    pingpong: Vec<bool>,
    ctx_depths: Vec<usize>,
}

impl ParamGrid {
    pub fn new(base: WindMillParams) -> Self {
        ParamGrid {
            base,
            pea_edges: Vec::new(),
            topologies: Vec::new(),
            smem_geoms: Vec::new(),
            sfu: Vec::new(),
            cpe: Vec::new(),
            pingpong: Vec::new(),
            ctx_depths: Vec::new(),
        }
    }

    /// Sweep the PEA edge (square arrays, Fig. 6a).
    pub fn pea_edges(mut self, edges: &[usize]) -> Self {
        self.pea_edges = edges.to_vec();
        self
    }

    /// Sweep the interconnect topology (Fig. 6c).
    pub fn topologies(mut self, topos: &[Topology]) -> Self {
        self.topologies = topos.to_vec();
        self
    }

    /// Sweep the shared-memory geometry as (banks, depth) pairs (Fig. 6c).
    pub fn smem_geoms(mut self, geoms: &[(usize, usize)]) -> Self {
        self.smem_geoms = geoms.to_vec();
        self
    }

    /// Sweep the SFU extension on/off (Fig. 6b PE-type mix).
    pub fn sfu(mut self, flags: &[bool]) -> Self {
        self.sfu = flags.to_vec();
        self
    }

    /// Sweep the controller-PE extension on/off (Fig. 6b PE-type mix).
    pub fn cpe(mut self, flags: &[bool]) -> Self {
        self.cpe = flags.to_vec();
        self
    }

    /// Sweep the ping-pong DMA extension on/off.
    pub fn pingpong(mut self, flags: &[bool]) -> Self {
        self.pingpong = flags.to_vec();
        self
    }

    /// Sweep the context-memory depth (configurations per PE). Points on
    /// this axis share every fabric parameter — under the stage-granular
    /// artifact cache they reuse one place/route artifact per
    /// `(kernel, seed)` and recompute only schedule analysis, config
    /// generation and simulation (see `coordinator::cache`).
    pub fn context_depths(mut self, depths: &[usize]) -> Self {
        self.ctx_depths = depths.to_vec();
        self
    }

    pub fn base(&self) -> &WindMillParams {
        &self.base
    }

    /// Number of raw axis combinations, before legality filtering.
    pub fn combinations(&self) -> usize {
        self.pea_edges.len().max(1)
            * self.topologies.len().max(1)
            * self.smem_geoms.len().max(1)
            * self.sfu.len().max(1)
            * self.cpe.len().max(1)
            * self.pingpong.len().max(1)
            * self.ctx_depths.len().max(1)
    }

    /// Number of runnable (legality-filtered) grid points, matching what
    /// [`ParamGrid::points`] yields — so `len() == 0 ⇔ is_empty()`.
    pub fn len(&self) -> usize {
        self.points().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of options on each of the grid's seven axes, in canonical
    /// axis order: edge, topology, smem geometry, sfu, cpe, pingpong,
    /// context depth. An unset axis counts 1 (pinned to base).
    pub fn axis_lens(&self) -> [usize; 7] {
        [
            self.pea_edges.len().max(1),
            self.topologies.len().max(1),
            self.smem_geoms.len().max(1),
            self.sfu.len().max(1),
            self.cpe.len().max(1),
            self.pingpong.len().max(1),
            self.ctx_depths.len().max(1),
        ]
    }

    /// Construct the labeled parameter set at one index tuple (canonical
    /// axis order, see [`ParamGrid::axis_lens`]). Indices on unset axes
    /// must be 0. Not legality-filtered — callers validate.
    fn point_at(&self, idx: [usize; 7]) -> (String, WindMillParams) {
        let mut p = self.base.clone();
        let mut label = String::new();
        if !self.pea_edges.is_empty() {
            let e = self.pea_edges[idx[0]];
            p.rows = e;
            p.cols = e;
            label.push_str(&format!("pea{e}-"));
        }
        if !self.topologies.is_empty() {
            let t = self.topologies[idx[1]];
            p.topology = t;
            label.push_str(&format!("{}-", t.name()));
        }
        if !self.smem_geoms.is_empty() {
            let (banks, depth) = self.smem_geoms[idx[2]];
            p.smem.banks = banks;
            p.smem.depth = depth;
            label.push_str(&format!("sm{banks}x{depth}-"));
        }
        if !self.sfu.is_empty() {
            let s = self.sfu[idx[3]];
            p.sfu_enabled = s;
            label.push_str(if s { "sfu-" } else { "nosfu-" });
        }
        if !self.cpe.is_empty() {
            let c = self.cpe[idx[4]];
            p.cpe_enabled = c;
            label.push_str(if c { "cpe-" } else { "nocpe-" });
        }
        if !self.pingpong.is_empty() {
            let d = self.pingpong[idx[5]];
            p.pingpong = d;
            label.push_str(if d { "pp-" } else { "nopp-" });
        }
        if !self.ctx_depths.is_empty() {
            let cd = self.ctx_depths[idx[6]];
            p.context_depth = cd;
            label.push_str(&format!("ctx{cd}-"));
        }
        if label.is_empty() {
            label.push_str("base-");
        }
        label.pop(); // trailing '-'
        (label, p)
    }

    /// Materialize the grid as labeled, *validated* parameter sets, in
    /// row-major axis order (last axis fastest). Points that hash equal —
    /// axis values may overlap, e.g. a repeated context depth — are
    /// emitted once, first label wins, so neither exhaustive sweeps nor
    /// search drivers ever pay for a point twice.
    pub fn points(&self) -> Vec<(String, WindMillParams)> {
        let lens = self.axis_lens();
        let total: usize = lens.iter().product();
        let mut out = Vec::with_capacity(total);
        let mut seen = std::collections::HashSet::with_capacity(total);
        for flat in 0..total {
            let mut rem = flat;
            let mut idx = [0usize; 7];
            for k in (0..7).rev() {
                idx[k] = rem % lens[k];
                rem /= lens[k];
            }
            let (label, p) = self.point_at(idx);
            if p.validate().is_ok() && seen.insert(p.stable_hash()) {
                out.push((label, p));
            }
        }
        out
    }

    /// Recover the axis indices of `params` on this grid, or `None` when
    /// the point lies off-grid on some set axis. Unset axes (pinned to
    /// base) are not compared — they always resolve to index 0.
    pub fn coords_of(&self, params: &WindMillParams) -> Option<[usize; 7]> {
        let mut idx = [0usize; 7];
        if !self.pea_edges.is_empty() {
            idx[0] = self
                .pea_edges
                .iter()
                .position(|&e| e == params.rows && e == params.cols)?;
        }
        if !self.topologies.is_empty() {
            idx[1] = self.topologies.iter().position(|&t| t == params.topology)?;
        }
        if !self.smem_geoms.is_empty() {
            idx[2] = self
                .smem_geoms
                .iter()
                .position(|&(b, d)| b == params.smem.banks && d == params.smem.depth)?;
        }
        if !self.sfu.is_empty() {
            idx[3] = self.sfu.iter().position(|&s| s == params.sfu_enabled)?;
        }
        if !self.cpe.is_empty() {
            idx[4] = self.cpe.iter().position(|&c| c == params.cpe_enabled)?;
        }
        if !self.pingpong.is_empty() {
            idx[5] = self.pingpong.iter().position(|&d| d == params.pingpong)?;
        }
        if !self.ctx_depths.is_empty() {
            idx[6] = self.ctx_depths.iter().position(|&cd| cd == params.context_depth)?;
        }
        Some(idx)
    }

    /// Grid points adjacent to `params` in index space: on each axis with
    /// more than one option, step the index by ±`radius` (clamped to the
    /// axis ends). `params` itself is excluded and candidates are
    /// validated and hash-deduplicated. Labels are exactly the ones
    /// [`ParamGrid::points`] assigns, so search drivers and exhaustive
    /// sweeps name the same design identically. Empty when `params` is
    /// off-grid.
    pub fn neighbors_at(
        &self,
        params: &WindMillParams,
        radius: usize,
    ) -> Vec<(String, WindMillParams)> {
        let Some(center) = self.coords_of(params) else {
            return Vec::new();
        };
        let lens = self.axis_lens();
        let r = radius.max(1);
        let mut out = Vec::new();
        let mut seen = std::collections::HashSet::new();
        seen.insert(params.stable_hash());
        for k in 0..7 {
            if lens[k] <= 1 {
                continue;
            }
            let lo = center[k].saturating_sub(r);
            let hi = (center[k] + r).min(lens[k] - 1);
            for cand in [lo, hi] {
                if cand == center[k] {
                    continue;
                }
                let mut idx = center;
                idx[k] = cand;
                let (label, p) = self.point_at(idx);
                if p.validate().is_ok() && seen.insert(p.stable_hash()) {
                    out.push((label, p));
                }
            }
        }
        out
    }

    /// Immediate (radius-1) grid neighborhood of `params`.
    pub fn neighbors(&self, params: &WindMillParams) -> Vec<(String, WindMillParams)> {
        self.neighbors_at(params, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn standard_matches_paper_counts() {
        let p = presets::standard();
        // Paper §IV-A.4: 28 LSUs; 8x8 grid => perimeter 28.
        assert_eq!(p.rows, 8);
        assert_eq!(p.cols, 8);
        assert_eq!(p.lsu_count(), 28);
        assert_eq!(p.count_of(PeType::Cpe), 1);
        assert_eq!(p.count_of(PeType::Gpe), 64 - 28 - 1);
        // Paper §IV-A.4: 16 banks of 256 x 32 bits.
        assert_eq!(p.smem.banks, 16);
        assert_eq!(p.smem.depth, 256);
        assert_eq!(p.smem.width_bits, 32);
        assert_eq!(p.smem.total_bits(), 16 * 256 * 32);
        assert_eq!(p.rca_count, 4);
        assert_eq!(p.freq_mhz, 750.0);
    }

    #[test]
    fn pe_type_map_boundary() {
        let p = presets::standard();
        assert_eq!(p.pe_type_at(0, 0), PeType::Lsu);
        assert_eq!(p.pe_type_at(0, 5), PeType::Lsu);
        assert_eq!(p.pe_type_at(7, 7), PeType::Lsu);
        assert_eq!(p.pe_type_at(1, 1), PeType::Cpe);
        assert_eq!(p.pe_type_at(3, 3), PeType::Gpe);
    }

    #[test]
    fn no_ring_all_gpe_except_cpe() {
        let mut p = presets::standard();
        p.lsu_ring = false;
        assert_eq!(p.count_of(PeType::Lsu), 0);
        assert_eq!(p.count_of(PeType::Cpe), 1);
    }

    #[test]
    fn scmd_multiplies_context() {
        let mut p = presets::standard();
        p.exec_mode = ExecMode::Mcmd;
        let mcmd = p.effective_context_depth();
        p.exec_mode = ExecMode::Scmd;
        assert_eq!(p.effective_context_depth(), mcmd * 8);
    }

    #[test]
    fn validation_catches_bad_geometry() {
        let mut p = presets::standard();
        p.rows = 1;
        assert!(p.validate().is_err());

        let mut p = presets::standard();
        p.smem.banks = 12; // not a power of two
        assert!(p.validate().is_err());

        let mut p = presets::standard();
        p.data_width = 24;
        assert!(p.validate().is_err());

        assert!(presets::standard().validate().is_ok());
    }

    #[test]
    fn out_of_bounds_panics() {
        let p = presets::standard();
        assert!(std::panic::catch_unwind(|| p.pe_type_at(8, 0)).is_err());
    }

    #[test]
    fn stable_hash_is_deterministic_and_field_sensitive() {
        let a = presets::standard();
        let b = presets::standard();
        assert_eq!(a.stable_hash(), b.stable_hash());
        let mut c = presets::standard();
        c.context_depth += 1;
        assert_ne!(a.stable_hash(), c.stable_hash());
        let mut d = presets::standard();
        d.topology = Topology::Torus;
        assert_ne!(a.stable_hash(), d.stable_hash());
        let mut e = presets::standard();
        e.smem.depth *= 2;
        assert_ne!(a.stable_hash(), e.stable_hash());
    }

    #[test]
    fn topology_hash_ignores_schedule_only_fields() {
        let a = presets::standard();
        // Schedule-only edits leave the fabric sub-hash untouched…
        let mut b = presets::standard();
        b.context_depth *= 2;
        b.exec_mode = ExecMode::Scmd;
        b.smem.depth *= 4;
        b.freq_mhz = 500.0;
        b.pingpong = !b.pingpong;
        assert_eq!(a.topology_hash(), b.topology_hash());
        assert_ne!(a.schedule_hash(), b.schedule_hash());
        assert_ne!(a.stable_hash(), b.stable_hash());
        // …while fabric edits change it.
        let edits: [fn(&mut WindMillParams); 5] = [
            |p| p.rows = 12,
            |p| p.topology = Topology::Torus,
            |p| p.sfu_enabled = false,
            |p| p.cpe_enabled = false,
            |p| p.lsu_ring = false,
        ];
        for edit in edits {
            let mut c = presets::standard();
            edit(&mut c);
            assert_ne!(a.topology_hash(), c.topology_hash(), "{c:?}");
            assert_eq!(a.schedule_hash(), c.schedule_hash(), "{c:?}");
        }
        // The three digests are domain-separated even on equal params.
        assert_ne!(a.topology_hash(), a.schedule_hash());
        assert_ne!(a.topology_hash(), a.stable_hash());
        assert_ne!(a.schedule_hash(), a.stable_hash());
    }

    /// The invariant the stage-granular cache keys rest on: every field of
    /// [`WindMillParams::stable_hash`] is covered by **exactly one** of
    /// `topology_hash` / `schedule_hash`. Each field is mutated in turn
    /// and the digests checked; the no-rest-pattern destructure below
    /// makes this test fail to *compile* when a field is added, forcing
    /// whoever adds it to place it in a sub-hash here.
    #[test]
    fn sub_hashes_partition_every_stable_hash_field() {
        // Compile-time exhaustiveness guard: adding a field to
        // `WindMillParams` (or `SmemParams`) breaks this destructure.
        let WindMillParams {
            rows: _,
            cols: _,
            data_width: _,
            topology: _,
            lsu_ring: _,
            cpe_enabled: _,
            sfu_enabled: _,
            context_depth: _,
            exec_mode: _,
            shared_reg_mode: _,
            shared_regs_per_group: _,
            smem: SmemParams { banks: _, depth: _, width_bits: _ },
            dma_width_bits: _,
            pingpong: _,
            rca_count: _,
            rtt_entries: _,
            freq_mhz: _,
        } = presets::standard();

        // (name, edit, belongs-to-topology-sub-hash)
        type Edit = fn(&mut WindMillParams);
        let fields: [(&str, Edit, bool); 19] = [
            ("rows", |p| p.rows += 1, true),
            ("cols", |p| p.cols += 1, true),
            ("data_width", |p| p.data_width = 64, true),
            ("topology", |p| p.topology = Topology::Torus, true),
            ("lsu_ring", |p| p.lsu_ring = !p.lsu_ring, true),
            ("cpe_enabled", |p| p.cpe_enabled = !p.cpe_enabled, true),
            ("sfu_enabled", |p| p.sfu_enabled = !p.sfu_enabled, true),
            ("context_depth", |p| p.context_depth *= 2, false),
            ("exec_mode", |p| p.exec_mode = ExecMode::Scmd, false),
            ("shared_reg_mode", |p| p.shared_reg_mode = SharedRegMode::GlobalShared, false),
            ("shared_regs_per_group", |p| p.shared_regs_per_group += 1, false),
            ("smem.banks", |p| p.smem.banks *= 2, false),
            ("smem.depth", |p| p.smem.depth *= 2, false),
            ("smem.width_bits", |p| p.smem.width_bits = 64, false),
            ("dma_width_bits", |p| p.dma_width_bits *= 2, false),
            ("pingpong", |p| p.pingpong = !p.pingpong, false),
            ("rca_count", |p| p.rca_count += 1, false),
            ("rtt_entries", |p| p.rtt_entries += 1, false),
            ("freq_mhz", |p| p.freq_mhz = 500.0, false),
        ];
        let base = presets::standard();
        for (name, edit, in_topology) in fields {
            let mut p = presets::standard();
            edit(&mut p);
            assert_ne!(base.stable_hash(), p.stable_hash(), "{name}: full hash must move");
            let topo_moved = base.topology_hash() != p.topology_hash();
            let sched_moved = base.schedule_hash() != p.schedule_hash();
            assert_eq!(
                topo_moved, in_topology,
                "{name}: expected in the {} sub-hash",
                if in_topology { "fabric" } else { "schedule" }
            );
            assert_ne!(
                topo_moved, sched_moved,
                "{name}: must be covered by exactly one sub-hash"
            );
        }
    }

    #[test]
    fn context_depth_axis_shares_the_fabric() {
        let grid = ParamGrid::new(presets::standard()).context_depths(&[16, 32, 64]);
        let points = grid.points();
        assert_eq!(points.len(), 3);
        assert_eq!(grid.combinations(), 3);
        let labels: Vec<&str> = points.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["ctx16", "ctx32", "ctx64"]);
        // All points share one topology_hash but have distinct arch hashes:
        // the precondition for stage-granular place/route reuse.
        let topo0 = points[0].1.topology_hash();
        let mut arch_hashes: Vec<u64> = Vec::new();
        for (_, p) in &points {
            assert_eq!(p.topology_hash(), topo0);
            arch_hashes.push(p.stable_hash());
        }
        arch_hashes.sort_unstable();
        arch_hashes.dedup();
        assert_eq!(arch_hashes.len(), 3);
    }

    #[test]
    fn param_grid_cartesian_product() {
        let grid = ParamGrid::new(presets::standard())
            .pea_edges(&[4, 8])
            .topologies(&Topology::ALL);
        assert_eq!(grid.len(), 6);
        let points = grid.points();
        assert_eq!(points.len(), 6);
        // Labels unique, params all valid.
        let mut labels: Vec<&str> = points.iter().map(|(l, _)| l.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), 6);
        for (_, p) in &points {
            p.validate().unwrap();
        }
    }

    #[test]
    fn param_grid_skips_illegal_points() {
        // Edge 2 under the LSU ring is illegal (needs ≥ 3x3) and must be
        // filtered, not abort the sweep.
        let grid = ParamGrid::new(presets::standard()).pea_edges(&[2, 4]);
        let points = grid.points();
        assert_eq!(points.len(), 1);
        assert_eq!(points[0].1.rows, 4);
    }

    #[test]
    fn param_grid_empty_axes_yield_base() {
        let grid = ParamGrid::new(presets::standard());
        let points = grid.points();
        assert_eq!(points.len(), 1);
        assert_eq!(points[0].0, "base");
        assert_eq!(points[0].1, presets::standard());
    }

    #[test]
    fn param_grid_dedups_overlapping_axis_values() {
        // Regression: a repeated axis value used to yield duplicate points
        // and sweeps paid for the same design twice. First label wins.
        let grid = ParamGrid::new(presets::standard()).context_depths(&[32, 16, 32]);
        let points = grid.points();
        assert_eq!(points.len(), 2);
        let labels: Vec<&str> = points.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["ctx32", "ctx16"]);
        // combinations() stays pre-filter, pre-dedup.
        assert_eq!(grid.combinations(), 3);
        assert_eq!(grid.len(), 2);
    }

    #[test]
    fn coords_round_trip_through_points() {
        let grid = ParamGrid::new(presets::standard())
            .pea_edges(&[4, 8])
            .topologies(&Topology::ALL)
            .context_depths(&[16, 64]);
        for (label, p) in grid.points() {
            let idx = grid.coords_of(&p).unwrap_or_else(|| panic!("{label} off-grid"));
            let (relabel, rebuilt) = grid.point_at(idx);
            assert_eq!(relabel, label);
            assert_eq!(rebuilt.stable_hash(), p.stable_hash());
        }
        // Off-grid on a set axis: no coordinates.
        let mut off = presets::standard();
        off.rows = 5;
        off.cols = 5;
        assert!(grid.coords_of(&off).is_none());
    }

    #[test]
    fn neighbors_step_each_set_axis_with_grid_labels() {
        let grid = ParamGrid::new(presets::standard())
            .pea_edges(&[4, 8, 12])
            .context_depths(&[16, 32, 64]);
        let all = grid.points();
        // Center of the grid: pea8 / ctx32.
        let center = &all.iter().find(|(l, _)| l == "pea8-ctx32").unwrap().1;
        let mut labels: Vec<String> =
            grid.neighbors(center).into_iter().map(|(l, _)| l).collect();
        labels.sort_unstable();
        assert_eq!(labels, vec!["pea12-ctx32", "pea4-ctx32", "pea8-ctx16", "pea8-ctx64"]);
        // Every neighbor label is a label points() would assign.
        let known: std::collections::HashSet<&str> =
            all.iter().map(|(l, _)| l.as_str()).collect();
        for l in &labels {
            assert!(known.contains(l.as_str()), "{l} not a grid label");
        }
        // Radius clamps at the axis ends and excludes the center itself.
        let corner = &all.iter().find(|(l, _)| l == "pea4-ctx16").unwrap().1;
        let far: Vec<String> =
            grid.neighbors_at(corner, 10).into_iter().map(|(l, _)| l).collect();
        assert_eq!(far, vec!["pea12-ctx16", "pea4-ctx64"]);
        // Off-grid center: empty.
        let mut off = presets::standard();
        off.rows = 5;
        off.cols = 5;
        assert!(grid.neighbors(&off).is_empty());
    }

    #[test]
    fn mutations_are_valid_distinct_and_deterministic() {
        let base = presets::standard();
        let muts = base.mutations();
        assert!(!muts.is_empty());
        let this = base.stable_hash();
        let mut hashes = std::collections::HashSet::new();
        for m in &muts {
            m.validate().unwrap();
            assert_ne!(m.stable_hash(), this);
            hashes.insert(m.stable_hash());
        }
        assert_eq!(hashes.len(), muts.len(), "mutations must be distinct");
        // Covers the advertised axes.
        assert!(muts.iter().any(|m| m.rows == base.rows + 1 && m.cols == base.cols + 1));
        assert!(muts.iter().any(|m| m.rows + 1 == base.rows && m.cols + 1 == base.cols));
        assert!(muts.iter().any(|m| m.context_depth == base.context_depth * 2));
        assert!(muts.iter().any(|m| m.context_depth * 2 == base.context_depth));
        assert!(muts.iter().any(|m| m.smem.banks == base.smem.banks * 2));
        assert!(muts.iter().any(|m| m.topology != base.topology));
        assert!(muts.iter().any(|m| m.pingpong != base.pingpong));
        // Deterministic order, and `mutated` draws reproducibly.
        assert_eq!(muts, base.mutations());
        let mut r1 = Rng::scoped(7, "t");
        let mut r2 = Rng::scoped(7, "t");
        assert_eq!(base.mutated(&mut r1), base.mutated(&mut r2));
        // A 3x3 LSU-ring array cannot shrink (needs ≥ 3x3): every mutation
        // stays legal.
        let mut small = presets::standard();
        small.rows = 3;
        small.cols = 3;
        for m in small.mutations() {
            assert!(m.rows >= 3 && m.cols >= 3);
        }
    }

    #[test]
    fn param_grid_extension_axes_and_emptiness() {
        // Fig. 6b PE-type mix: SFU x CPE ablation grid.
        let grid = ParamGrid::new(presets::standard())
            .sfu(&[true, false])
            .cpe(&[true, false]);
        let points = grid.points();
        assert_eq!(points.len(), 4);
        assert!(!grid.is_empty());
        assert!(points.iter().any(|(l, p)| l == "nosfu-nocpe" && !p.sfu_enabled && !p.cpe_enabled));
        // A grid whose only configured edge is illegal filters to nothing:
        // len()/is_empty() agree post-filter, combinations() is pre-filter.
        let degenerate = ParamGrid::new(presets::standard()).pea_edges(&[2]);
        assert!(degenerate.is_empty());
        assert_eq!(degenerate.len(), 0);
        assert_eq!(degenerate.combinations(), 1);
    }
}
