//! Adaptive-DSE system tests (PR 7): search-guided sweeps must reach the
//! exhaustive Pareto frontier while evaluating fewer points — and must
//! compose with every existing guarantee. Pinned here:
//!
//! * both shipped drivers ([`SuccessiveHalving`], [`Evolutionary`]) reach a
//!   frontier dominance-equivalent to the exhaustive sweep on a grid whose
//!   frontier is known analytically;
//! * a fixed seed makes a drive fully deterministic — same evaluated
//!   points, same order, same frontier;
//! * a re-drive on a warm store performs **zero** `simulate()` calls and
//!   reproduces the cold report bit-for-bit, and the drive's wave records
//!   land in `manifest.jsonl` without confusing the shard-session reader;
//! * an out-of-grid point produced by the mutation operator round-trips
//!   the persistent store like any grid point (the codec has no grid
//!   enumeration to lean on — parameters travel by value).

use std::path::PathBuf;
use std::sync::Arc;

use windmill::arch::params::ParamGrid;
use windmill::arch::presets;
use windmill::coordinator::{
    Evolutionary, SuccessiveHalving, SweepEngine, SweepReport, Workload, WorkloadSuite,
};
use windmill::store::{DiskStore, SweepSession};
use windmill::util::Rng;

/// Unique per-test scratch directory, removed on drop.
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let dir =
            std::env::temp_dir().join(format!("windmill-dsetest-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }

    fn path(&self) -> &std::path::Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Every frontier point of `reference` is matched (same architecture) or
/// weakly dominated by some frontier point of `search` — the search lost
/// nothing the reference found. This is the acceptance notion: the search
/// may surface a *different* representative only if it is at least as good
/// on every objective.
fn assert_frontier_covers(search: &SweepReport, reference: &SweepReport, what: &str) {
    for e in reference.frontier_points() {
        let covered = search
            .frontier_points()
            .iter()
            .any(|d| d.arch_hash == e.arch_hash || d.dominates(e));
        assert!(covered, "{what}: `{}` uncovered by the searched frontier", e.label);
    }
}

/// Context-depth chain at or above the standard 32: for saxpy-64 the
/// iteration window never binds, so cycles are identical across the chain
/// while area and power grow strictly with depth — the exhaustive frontier
/// is exactly the minimum-depth point, known without running the search.
fn ctx_chain() -> ParamGrid {
    ParamGrid::new(presets::standard()).context_depths(&[32, 64, 128])
}

fn suite() -> WorkloadSuite {
    WorkloadSuite::single(Workload::Saxpy { n: 64 })
}

#[test]
fn each_strategy_matches_the_exhaustive_frontier() {
    let grid = ctx_chain();
    let exhaustive = SweepEngine::new(2).sweep_suite(&grid, &suite(), 42);
    assert!(exhaustive.failures.is_empty(), "{:?}", exhaustive.failures);
    assert_eq!(exhaustive.points_evaluated(), grid.len());

    // Halving only ever proposes grid points, so its frontier and the
    // exhaustive one must cover each other (dominance-equivalence).
    let mut halving = SuccessiveHalving::new(&grid, 42);
    let driven_h = SweepEngine::new(2).drive(&grid, &suite(), 42, &mut halving);
    assert!(driven_h.failures.is_empty(), "{:?}", driven_h.failures);
    assert_frontier_covers(&driven_h, &exhaustive, "halving");
    assert_frontier_covers(&exhaustive, &driven_h, "halving (reverse)");

    // Evolution may step *off* the grid and land on strictly better
    // points, so the guarantee is one-directional: it loses nothing the
    // exhaustive sweep found. (Exotic mutants may legitimately fail to
    // map — failures are contained, not fatal.)
    let mut evolve = Evolutionary::new(&grid, 42);
    let driven_e = SweepEngine::new(2).drive(&grid, &suite(), 42, &mut evolve);
    assert_frontier_covers(&driven_e, &exhaustive, "evolve");

    // The headline metric is visible: the drive knows the grid size and
    // reports the searched fraction (proposals are deduplicated, so the
    // in-grid evaluations never exceed it; mutation may step off-grid).
    assert_eq!(driven_h.grid_size, grid.len());
    assert!(
        driven_h.summary().contains("searched"),
        "summary must report the searched fraction: {}",
        driven_h.summary()
    );
    assert!(driven_e.summary().contains("searched"));
}

#[test]
fn drivers_are_deterministic_for_a_fixed_seed() {
    let grid = ParamGrid::new(presets::standard()).pea_edges(&[4, 6, 8]).context_depths(&[32, 64]);
    let run_halving = || {
        let mut d = SuccessiveHalving::new(&grid, 7);
        SweepEngine::new(2).drive(&grid, &suite(), 7, &mut d)
    };
    let run_evolve = || {
        let mut d = Evolutionary::new(&grid, 7);
        SweepEngine::new(2).drive(&grid, &suite(), 7, &mut d)
    };
    for (a, b, what) in [
        (run_halving(), run_halving(), "halving"),
        (run_evolve(), run_evolve(), "evolve"),
    ] {
        let labels = |r: &SweepReport| r.points.iter().map(|p| p.label.clone()).collect::<Vec<_>>();
        assert_eq!(labels(&a), labels(&b), "{what}: evaluated point sequence must be reproducible");
        for (x, y) in a.points.iter().zip(b.points.iter()) {
            assert_eq!(x.cycles, y.cycles, "{what}: {}", x.label);
            assert_eq!(x.wm_time_ns.to_bits(), y.wm_time_ns.to_bits(), "{what}: {}", x.label);
        }
        let front = |r: &SweepReport| {
            r.frontier_points().iter().map(|p| p.label.clone()).collect::<Vec<_>>()
        };
        assert_eq!(front(&a), front(&b), "{what}: frontier must be reproducible");
    }
}

#[test]
fn warm_store_re_drive_performs_zero_simulate_calls() {
    let tmp = TempDir::new("warm-drive");
    let grid = ParamGrid::new(presets::standard()).pea_edges(&[4, 6]).context_depths(&[32, 64]);

    let store = Arc::new(DiskStore::open(tmp.path()).unwrap());
    let mut driver = SuccessiveHalving::new(&grid, 7);
    let cold = SweepEngine::with_store(2, store).drive(&grid, &suite(), 7, &mut driver);
    assert!(cold.failures.is_empty(), "{:?}", cold.failures);
    assert!(
        cold.cache.pass_counts_full("simulate").miss > 0,
        "cold drive must actually simulate"
    );

    // Wave records landed in the manifest — and do not confuse the
    // shard-session reader (no shard entries, nothing counted as garbage).
    let waves = SweepSession::read_waves(tmp.path());
    assert!(!waves.is_empty(), "drive with a store must record its waves");
    for (i, w) in waves.iter().enumerate() {
        assert_eq!(w.driver, "halving");
        assert_eq!(w.suite, suite().name());
        assert_eq!(w.seed, 7);
        assert_eq!(w.wave, i as u32);
        assert!(w.evaluated <= w.proposed, "wave {i}: dedup only removes proposals");
    }
    assert_eq!(waves.iter().map(|w| w.evaluated).sum::<usize>(), cold.points_evaluated());
    let (entries, skipped) = SweepSession::read_manifest(tmp.path());
    assert!(entries.is_empty(), "wave records must not read back as shard entries");
    assert_eq!(skipped, 0, "wave records must not be counted as garbage");

    // A cold process on the warm store: same drive, zero simulate() calls,
    // bit-identical report.
    let store2 = Arc::new(DiskStore::open(tmp.path()).unwrap());
    let mut driver2 = SuccessiveHalving::new(&grid, 7);
    let warm = SweepEngine::with_store(2, store2).drive(&grid, &suite(), 7, &mut driver2);
    assert!(warm.failures.is_empty(), "{:?}", warm.failures);
    let sim = warm.cache.pass_counts_full("simulate");
    assert_eq!(sim.miss, 0, "warm re-drive must not re-enter simulate()");
    assert_eq!(warm.sim_hit_rate(), 1.0);
    assert_eq!(warm.points.len(), cold.points.len());
    for (a, b) in warm.points.iter().zip(cold.points.iter()) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.cycles, b.cycles, "{}", a.label);
        assert_eq!(a.wm_time_ns.to_bits(), b.wm_time_ns.to_bits(), "{}", a.label);
        assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits(), "{}", a.label);
    }
}

/// The mutation operator steps off every enumerated grid (that is its
/// point); such a point must flow through the persistent store exactly
/// like a grid point — parameters travel by value, not by grid index.
#[test]
fn out_of_grid_mutated_point_round_trips_the_store() {
    let tmp = TempDir::new("mutant");
    let mut rng = Rng::scoped(7, "test.mutant");
    let mutant = presets::standard().mutated(&mut rng).expect("standard preset has mutations");
    assert!(mutant.validate().is_ok());
    assert_ne!(mutant.stable_hash(), presets::standard().stable_hash());

    let run = || {
        let store = Arc::new(DiskStore::open(tmp.path()).unwrap());
        SweepEngine::with_store(1, store).sweep_points(
            vec![("mutant".to_string(), mutant.clone())],
            &suite(),
            7,
        )
    };
    let cold = run();
    assert!(cold.failures.is_empty(), "{:?}", cold.failures);
    assert!(cold.cache.pass_counts_full("simulate").miss > 0);
    assert_eq!(cold.grid_size, 1);

    let warm = run();
    assert!(warm.failures.is_empty(), "{:?}", warm.failures);
    assert_eq!(warm.cache.pass_counts_full("simulate").miss, 0, "mutant must warm-start");
    assert_eq!(warm.points[0].cycles, cold.points[0].cycles);
    assert_eq!(warm.points[0].arch_hash, cold.points[0].arch_hash);
}
