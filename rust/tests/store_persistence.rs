//! Persistent-store system tests: codec round-trips under randomization,
//! corruption recovery, same-directory concurrency, the cross-process
//! warm-start guarantee (a cold process on a warm store performs zero
//! elaborations, zero mapper invocations and zero `simulate()` calls), and
//! shard-merge bit-identity with the unsharded sweep.

use std::path::PathBuf;
use std::sync::Arc;

use windmill::arch::params::ParamGrid;
use windmill::arch::{presets, Topology};
use windmill::coordinator::{
    run_job_cached, ArtifactCache, JobSpec, SweepEngine, SweepReport, Workload, WorkloadSuite,
};
use windmill::store::codec::{
    decode_mapping, decode_sim, decode_sweep_partial, encode_mapping, encode_sim,
    encode_sweep_partial, SweepPartial,
};
use windmill::store::{DiskStore, SweepSession};
use windmill::util::Rng;

/// Unique per-test scratch directory, removed on drop.
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let dir = std::env::temp_dir()
            .join(format!("windmill-storetest-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }

    fn path(&self) -> &std::path::Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// The grid the CLI `sweep` verb runs (mirrored here so the cross-process
/// test drives the exact same points through the binary).
fn cli_grid() -> ParamGrid {
    ParamGrid::new(presets::standard()).pea_edges(&[4, 8, 12, 16]).topologies(&Topology::ALL)
}

fn small_grid() -> ParamGrid {
    ParamGrid::new(presets::standard()).pea_edges(&[4, 8]).topologies(&Topology::ALL)
}

// ---------------------------------------------------------------------------
// Codec property tests
// ---------------------------------------------------------------------------

/// Round-trip randomized *real* mappings and simulation results: compile
/// and simulate randomized kernels, then require decode(encode(x)) to
/// reproduce every field and encode(decode(bytes)) == bytes (canonical
/// form — HashMap-backed structures serialize sorted).
#[test]
fn codec_roundtrips_randomized_real_artifacts() {
    let machine = windmill::plugins::elaborate(presets::standard()).unwrap().artifact;
    let mut rng = Rng::new(0xC0DEC);
    for round in 0..6 {
        let (dfg, layout) = match rng.range(0, 4) {
            0 => windmill::workloads::linalg::saxpy(16 << rng.range(0, 3), 2.5),
            1 => windmill::workloads::linalg::dot(32 << rng.range(0, 2)),
            2 => windmill::workloads::linalg::gemm_bias(4, 4, 1 << rng.range(1, 4)),
            _ => windmill::workloads::linalg::spmv_csr(8, 16, 2 + rng.range(0, 3) as u32),
        };
        let seed = rng.next_u64();
        let (mapping, ns) =
            windmill::compiler::compile_timed(dfg, &machine, seed).unwrap();
        let bytes = encode_mapping(&mapping, &ns);
        let (back, back_ns) = decode_mapping(&bytes).unwrap();
        assert_eq!(back.dfg.stable_hash(), mapping.dfg.stable_hash(), "round {round}");
        assert_eq!(back.place, mapping.place);
        assert_eq!(back.schedule, mapping.schedule);
        assert_eq!(back.routes.edges, mapping.routes.edges);
        assert_eq!(back.routes.through_load, mapping.routes.through_load);
        assert_eq!(back_ns, ns);
        assert_eq!(encode_mapping(&back, &back_ns), bytes, "canonical re-encode");

        // Simulate on a NaN-free random image and round-trip the result.
        let words = machine.smem.as_ref().unwrap().words().max(layout.total_words() as usize);
        let image: Vec<f32> = (0..words).map(|_| rng.normal()).collect();
        if let Ok(sim) = windmill::sim::engine::simulate(&mapping, &machine, &image, 4_000_000)
        {
            let sbytes = encode_sim(&sim);
            let sback = decode_sim(&sbytes).unwrap();
            assert_eq!(sback.cycles, sim.cycles);
            assert_eq!(
                sback.mem.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                sim.mem.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "image bits survive"
            );
            assert_eq!(sback.smem, sim.smem);
            assert_eq!(encode_sim(&sback), sbytes);
        }
    }
}

/// Sweep partials carry full-width `u64` hashes (arch hashes are FNV
/// digests that routinely exceed 2^53 — the range `util::json`'s `f64`
/// numbers silently truncate). Fuzz partials with such hashes and extreme
/// floats; every bit must survive.
#[test]
fn codec_roundtrips_partials_with_hashes_above_2_53() {
    let mut rng = Rng::new(0xFEED);
    for round in 0..16 {
        let engine = SweepEngine::new(1);
        let grid = ParamGrid::new(presets::standard()).pea_edges(&[4]);
        let suite =
            WorkloadSuite::new(vec![Workload::Saxpy { n: 32 }, Workload::Dot { n: 32 }])
                .unwrap();
        let mut partial =
            SweepSession::run_shard(&engine, &grid, &suite, rng.next_u64(), 0, 1).unwrap();
        // Force the hash ranges JSON would corrupt.
        partial.grid_hash = rng.next_u64() | (1 << 63);
        partial.suite_hash = rng.next_u64() | (1 << 63);
        for p in &mut partial.report.points {
            p.arch_hash = (1u64 << 53) + 1 + rng.next_u64() % (1u64 << 20);
            p.wm_time_ns = f64::from_bits(0x7FEF_FFFF_FFFF_FFFF); // f64::MAX
        }
        let bytes = encode_sweep_partial(&partial);
        let back: SweepPartial = decode_sweep_partial(&bytes).unwrap();
        assert_eq!(back.grid_hash, partial.grid_hash, "round {round}");
        assert_eq!(back.suite_hash, partial.suite_hash, "suite fingerprint verbatim");
        assert_eq!(back.suite, partial.suite);
        for (a, b) in back.report.points.iter().zip(partial.report.points.iter()) {
            assert_eq!(a.arch_hash, b.arch_hash, "hash above 2^53 must be verbatim");
            assert!((1u64 << 53) < a.arch_hash);
            assert_eq!(a.wm_time_ns.to_bits(), b.wm_time_ns.to_bits());
            assert_eq!(a.label, b.label);
            // Suite columns survive with their bit patterns.
            assert_eq!(a.per_workload.len(), 2);
            for (x, y) in a.per_workload.iter().zip(b.per_workload.iter()) {
                assert_eq!(x.workload, y.workload);
                assert_eq!(x.cycles, y.cycles);
                assert_eq!(x.wm_time_ns.to_bits(), y.wm_time_ns.to_bits());
            }
        }
        assert_eq!(back.report.frontier, partial.report.frontier);
        assert_eq!(back.report.rejected_nonfinite, partial.report.rejected_nonfinite);
        assert_eq!(encode_sweep_partial(&back), bytes);
    }
}

/// A partial written by an older codec version must be *skipped and
/// counted* by `load_partials` — never fatal, never silently merged. (We
/// forge a v1 header with a valid digest: the version check itself has to
/// reject it, not the checksum.)
#[test]
fn old_version_partials_are_skipped_and_counted() {
    let tmp = TempDir::new("stale-partial");
    let engine = SweepEngine::new(1);
    let grid = ParamGrid::new(presets::standard()).pea_edges(&[4]);
    let suite = WorkloadSuite::single(Workload::Saxpy { n: 32 });
    let p = SweepSession::run_shard(&engine, &grid, &suite, 42, 0, 1).unwrap();
    let path = SweepSession::save_partial(tmp.path(), &p).unwrap();

    // Forge a stale-version sibling: patch VERSION (bytes 4..6) to 1 and
    // recompute the trailing FNV digest so only the version check trips.
    let mut stale = std::fs::read(&path).unwrap();
    stale[4..6].copy_from_slice(&1u16.to_le_bytes());
    let n = stale.len();
    let digest = windmill::util::hash::fnv1a(&stale[..n - 8]);
    stale[n - 8..].copy_from_slice(&digest.to_le_bytes());
    std::fs::write(path.with_file_name("stale-v1.bin"), &stale).unwrap();

    let (partials, skipped) = SweepSession::load_partials(tmp.path()).unwrap();
    assert_eq!(partials.len(), 1, "the v2 partial loads");
    assert_eq!(skipped, 1, "the v1 partial is counted, not fatal");
    let merged = SweepSession::merge(partials).unwrap();
    assert_eq!(merged.points.len(), 1);
}

// ---------------------------------------------------------------------------
// Corruption recovery
// ---------------------------------------------------------------------------

/// Truncated or corrupted entries must degrade into recomputes: the cache
/// skips them, repopulates the slot, and the job result is unaffected.
#[test]
fn corrupted_store_entries_recover_by_recompute() {
    let tmp = TempDir::new("corrupt-recover");
    let store = Arc::new(DiskStore::open(tmp.path()).unwrap());
    let spec = JobSpec {
        workload: Workload::Saxpy { n: 64 },
        params: presets::standard(),
        seed: 3,
    };

    let warm = ArtifactCache::new().with_store(Arc::clone(&store));
    let (baseline, _) = run_job_cached(&spec, Some(&warm)).unwrap();
    assert!(store.entry_count() >= 3, "elab + mapping + sim persisted");

    // Vandalize every persisted entry: truncate half, bit-flip the rest.
    let mut n_files = 0;
    for pass in std::fs::read_dir(tmp.path()).unwrap().flatten() {
        if !pass.path().is_dir() {
            continue;
        }
        for f in std::fs::read_dir(pass.path()).unwrap().flatten() {
            let bytes = std::fs::read(f.path()).unwrap();
            let mangled = if n_files % 2 == 0 {
                bytes[..bytes.len() / 3].to_vec()
            } else {
                let mut b = bytes.clone();
                let mid = b.len() / 2;
                b[mid] ^= 0xA5;
                b
            };
            std::fs::write(f.path(), mangled).unwrap();
            n_files += 1;
        }
    }
    assert!(n_files >= 3);

    // A cold cache on the vandalized store must recompute — and succeed.
    let cold = ArtifactCache::new().with_store(Arc::clone(&store));
    let (recovered, timing) = run_job_cached(&spec, Some(&cold)).unwrap();
    assert_eq!(recovered.cycles, baseline.cycles);
    assert_eq!(recovered.mem, baseline.mem, "recompute is bit-identical");
    // Every entry carries a trailing FNV digest, so truncations *and*
    // mid-payload bit flips are all unreadable — nothing decodes, nothing
    // is silently wrong, every lookup recomputes.
    assert!(timing.cache_misses >= 3, "nothing decodable => misses ({timing:?})");
    assert_eq!(timing.cache_hits, 0, "vandalized entries must not hit ({timing:?})");
    assert!(store.stats().corrupt >= 3, "{:?}", store.stats());

    // The recompute rewrote the slots: a third cold cache is fully warm.
    let final_check = ArtifactCache::new().with_store(Arc::clone(&store));
    let (again, t3) = run_job_cached(&spec, Some(&final_check)).unwrap();
    assert_eq!(again.mem, baseline.mem);
    assert_eq!(t3.cache_misses, 0, "repaired store warm-starts ({t3:?})");
}

// ---------------------------------------------------------------------------
// Concurrency: two store handles, one directory
// ---------------------------------------------------------------------------

/// Two independent `DiskStore` handles (as two processes would hold) sweep
/// the same grid into one directory concurrently. Atomic tmp+rename writes
/// mean no torn entries: afterwards a third cold cache warm-starts with
/// zero recomputes.
#[test]
fn concurrent_stores_share_one_directory_safely() {
    let tmp = TempDir::new("concurrent");
    let dir = tmp.path().to_path_buf();
    let wl = Workload::Dot { n: 128 };

    let mut handles = Vec::new();
    for worker in 0..2 {
        let dir = dir.clone();
        let wl = wl.clone();
        handles.push(std::thread::spawn(move || {
            let store = Arc::new(DiskStore::open(&dir).unwrap());
            let engine = SweepEngine::with_store(2, store);
            let r = engine.sweep_seeded(&small_grid(), &wl, 42);
            assert!(r.failures.is_empty(), "worker {worker}: {:?}", r.failures);
            r.points.len()
        }));
    }
    let counts: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(counts[0], counts[1]);

    // No temp-file litter, and a cold third process is fully warm.
    for pass in std::fs::read_dir(&dir).unwrap().flatten() {
        if pass.path().is_dir() {
            for f in std::fs::read_dir(pass.path()).unwrap().flatten() {
                let name = f.file_name().to_string_lossy().to_string();
                assert!(!name.starts_with(".tmp"), "leftover temp file {name}");
            }
        }
    }
    let store = Arc::new(DiskStore::open(&dir).unwrap());
    let engine = SweepEngine::with_store(2, store);
    let warm = engine.sweep_seeded(&small_grid(), &wl, 42);
    assert_eq!(warm.cache.misses, 0, "third process recomputes nothing: {:?}", warm.cache);
    assert_eq!(warm.sim_hit_rate(), 1.0);
}

// ---------------------------------------------------------------------------
// The acceptance bar: cross-process warm start via the real binary
// ---------------------------------------------------------------------------

/// Process 1 is the actual `windmill` CLI populating a store; process 2 is
/// this test with a cold in-memory cache on the same directory. The cold
/// process must complete the CLI's Fig. 6 grid with zero elaborations,
/// zero mapper invocations and zero `simulate()` calls.
#[test]
fn cold_process_on_warm_store_recomputes_nothing() {
    let tmp = TempDir::new("cross-process");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_windmill"))
        .args(["sweep", "saxpy", "--workers", "2", "--store"])
        .arg(tmp.path())
        .output()
        .expect("spawn windmill sweep");
    assert!(
        out.status.success(),
        "CLI sweep failed:\n{}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let store = Arc::new(DiskStore::open(tmp.path()).unwrap());
    assert!(store.entry_count() > 0, "process 1 persisted artifacts");

    // Process 2: cold memory, warm store — the CLI's exact grid/seed.
    let engine = SweepEngine::with_store(2, Arc::clone(&store));
    let report = engine.sweep_seeded(&cli_grid(), &Workload::Saxpy { n: 256 }, 42);
    assert!(report.failures.is_empty(), "{:?}", report.failures);
    for pass in ["elaborate", "mapping", "simulate"] {
        let c = report.cache.pass_counts_full(pass);
        assert_eq!(c.miss, 0, "cold process re-ran `{pass}`: {:?}", report.cache);
        assert!(c.disk > 0, "`{pass}` must warm-start from disk: {:?}", report.cache);
    }
    assert_eq!(report.sim_hit_rate(), 1.0);
    assert_eq!(report.cache.misses, 0);

    // And the disk-warmed numbers equal a from-scratch sweep bit-for-bit.
    let fresh = SweepEngine::new(2).sweep_seeded(&cli_grid(), &Workload::Saxpy { n: 256 }, 42);
    let key = |r: &SweepReport| {
        let mut v: Vec<(String, u64, u64)> = r
            .points
            .iter()
            .map(|p| (p.label.clone(), p.cycles, p.wm_time_ns.to_bits()))
            .collect();
        v.sort();
        v
    };
    assert_eq!(key(&report), key(&fresh));
}

/// The `--expect-warm` CI verb: a second CLI process on the same store
/// must see a 100% sim hit rate (and fail loudly when pointed at nothing).
#[test]
fn cli_expect_warm_gates_on_sim_hit_rate() {
    let tmp = TempDir::new("expect-warm");
    let run = |extra: &[&str]| {
        let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_windmill"));
        cmd.args(["sweep", "dot", "--workers", "2", "--store"]).arg(tmp.path());
        cmd.args(extra);
        cmd.output().expect("spawn windmill sweep")
    };
    let cold = run(&["--expect-warm"]);
    assert!(!cold.status.success(), "cold sweep cannot claim warmth");
    let populate = run(&[]);
    assert!(populate.status.success());
    let warm = run(&["--expect-warm"]);
    assert!(
        warm.status.success(),
        "warm store must pass --expect-warm:\n{}",
        String::from_utf8_lossy(&warm.stderr)
    );
}

// ---------------------------------------------------------------------------
// Shard / merge equivalence
// ---------------------------------------------------------------------------

/// `SweepSession::merge` of N shards is bit-identical to the unsharded
/// report: same point order, same values to the bit, same frontier
/// indices — for every shard count that divides the grid or doesn't.
#[test]
fn shard_merge_is_bit_identical_to_unsharded_sweep() {
    let suite = WorkloadSuite::single(Workload::Saxpy { n: 64 });
    let grid = small_grid();
    let full = SweepEngine::new(2).sweep_suite(&grid, &suite, 42);
    assert!(!full.points.is_empty());

    for shards in [1usize, 2, 3, full.points.len()] {
        let partials: Vec<_> = (0..shards)
            .map(|i| {
                // Each shard in its own engine = its own process image.
                let engine = SweepEngine::new(2);
                SweepSession::run_shard(&engine, &grid, &suite, 42, i, shards).unwrap()
            })
            .collect();
        let merged = SweepSession::merge(partials).unwrap();
        assert_eq!(merged.points.len(), full.points.len(), "shards={shards}");
        for (a, b) in merged.points.iter().zip(full.points.iter()) {
            assert_eq!(a.label, b.label, "point order preserved (shards={shards})");
            assert_eq!(a.cycles, b.cycles);
            assert_eq!(a.arch_hash, b.arch_hash);
            assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits());
            assert_eq!(a.power_mw.to_bits(), b.power_mw.to_bits());
            assert_eq!(a.wm_time_ns.to_bits(), b.wm_time_ns.to_bits());
        }
        assert_eq!(merged.frontier, full.frontier, "frontier indices (shards={shards})");
        assert_eq!(merged.failures, full.failures);
    }
}

/// The acceptance bar for suite sessions: a 2-shard *suite* sweep (three
/// aspects: linalg gemm, non-affine spmv, multi-phase rl-step) over a
/// context-depth grid merges `to_bits`-identically to the unsharded suite
/// sweep — per-workload columns included — and the whole suite places and
/// routes each kernel exactly once per seed across all points.
#[test]
fn suite_shard_merge_is_bit_identical_and_reuses_place_route() {
    let suite = WorkloadSuite::new(vec![
        Workload::Gemm { m: 8, n: 8, k: 8 },
        Workload::Spmv { rows: 16, cols: 24, k: 4 },
        Workload::RlStep,
    ])
    .unwrap();
    // Context-depth-only grid: the fabric sub-hash is constant, so
    // place/route must be computed once per (kernel, seed), suite-wide.
    let grid = ParamGrid::new(presets::standard()).context_depths(&[32, 64]);
    let full_engine = SweepEngine::new(1); // sequential ⇒ exact counts
    let full = full_engine.sweep_suite(&grid, &suite, 42);
    assert!(full.failures.is_empty(), "{:?}", full.failures);
    assert_eq!(full.points.len(), 2);

    // gemm (1 dfg) + spmv (1 dfg) + rl-step (8 phases) = 10 kernels.
    let n_kernels = suite.workloads().iter().map(|w| w.build().0.len() as u64).sum::<u64>();
    assert_eq!(n_kernels, 10);
    for pass in ["place", "route"] {
        let c = full.cache.pass_counts_full(pass);
        assert_eq!(c.miss, n_kernels, "{pass}: once per kernel, suite-wide: {:?}", full.cache);
        assert_eq!(c.mem, n_kernels, "{pass}: second depth reuses: {:?}", full.cache);
    }
    // One elaboration per grid point, shared by all three members.
    assert_eq!(full.cache.pass_counts_full("elaborate").miss, 2, "{:?}", full.cache);

    // 2-shard merge reproduces the report bit-for-bit.
    let partials: Vec<_> = (0..2)
        .map(|i| {
            let engine = SweepEngine::new(1);
            SweepSession::run_shard(&engine, &grid, &suite, 42, i, 2).unwrap()
        })
        .collect();
    let merged = SweepSession::merge(partials).unwrap();
    assert_eq!(merged.points.len(), full.points.len());
    for (a, b) in merged.points.iter().zip(full.points.iter()) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.wm_time_ns.to_bits(), b.wm_time_ns.to_bits());
        assert_eq!(a.per_workload.len(), 3);
        for (x, y) in a.per_workload.iter().zip(b.per_workload.iter()) {
            assert_eq!(x.workload, y.workload);
            assert_eq!(x.cycles, y.cycles);
            assert_eq!(x.wm_time_ns.to_bits(), y.wm_time_ns.to_bits());
            assert_eq!(x.speedup_vs_gpu.to_bits(), y.speedup_vs_gpu.to_bits());
        }
    }
    assert_eq!(merged.frontier, full.frontier);
    assert_eq!(merged.rejected_nonfinite, full.rejected_nonfinite);
}

/// End-to-end sharding through the CLI: two shard processes + a merge
/// process, against one store directory.
#[test]
fn cli_shard_processes_merge_to_the_full_frontier() {
    let tmp = TempDir::new("cli-shards");
    let run = |args: &[&str]| {
        let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_windmill"));
        cmd.args(args).arg("--store").arg(tmp.path());
        cmd.output().expect("spawn windmill")
    };
    for shard in ["0/2", "1/2"] {
        let out = run(&["sweep", "dot", "--workers", "2", "--shard", shard]);
        assert!(
            out.status.success(),
            "shard {shard} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
    // An unrelated half-finished session in the same store (a different
    // shard count) must not poison the merge of the complete one.
    let stale = run(&["sweep", "dot", "--workers", "2", "--shard", "0/3"]);
    assert!(stale.status.success());
    let merged = run(&["sweep-merge"]);
    assert!(merged.status.success(), "{}", String::from_utf8_lossy(&merged.stderr));
    let merged_out = String::from_utf8_lossy(&merged.stdout).to_string();

    // The merged frontier lines must be byte-identical to the unsharded
    // sweep's (same format as the CLI prints).
    let full = SweepEngine::new(2).sweep_seeded(&cli_grid(), &Workload::Dot { n: 256 }, 42);
    for p in full.frontier_points() {
        let line = format!(
            "  * {:<20} {:>7.3} mm2  {:>6.2} mW  {:>9} cycles",
            p.label, p.area_mm2, p.power_mw, p.cycles
        );
        assert!(merged_out.contains(&line), "missing frontier line `{line}` in:\n{merged_out}");
    }
}

// ---------------------------------------------------------------------------
// Eviction + store interplay
// ---------------------------------------------------------------------------

/// With a byte budget, evicted `SimResult`s re-load from disk: the warm
/// re-run still performs zero `simulate()` calls even though memory only
/// ever holds one image.
#[test]
fn evicted_sim_results_reload_from_disk_not_recompute() {
    let tmp = TempDir::new("evict-reload");
    let store = Arc::new(DiskStore::open(tmp.path()).unwrap());
    let cache = Arc::new(
        ArtifactCache::new().with_store(Arc::clone(&store)).with_sim_budget(1),
    );
    let engine = SweepEngine::with_cache(2, Arc::clone(&cache));
    let wl = Workload::Saxpy { n: 64 };

    let cold = engine.sweep_seeded(&small_grid(), &wl, 42);
    assert!(cold.failures.is_empty());
    assert!(cold.cache.evictions > 0, "budget of 1 byte must evict: {:?}", cold.cache);
    assert_eq!(cache.sim_bytes_cached(), 0, "nothing stays resident");

    let warm = engine.sweep_seeded(&small_grid(), &wl, 42);
    let sim = warm.cache.pass_counts_full("simulate");
    assert_eq!(sim.miss, 0, "evictions must not cost recomputes: {:?}", warm.cache);
    assert!(sim.disk > 0, "warm path is the disk tier: {:?}", warm.cache);
    assert_eq!(warm.sim_hit_rate(), 1.0);
}
