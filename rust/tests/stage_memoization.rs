//! Stage-granular compile memoization (PR 4): correctness of the
//! place/route/schedule cache tiers across the full sweep stack.
//!
//! The claims under test, end to end:
//!
//! 1. A cold sweep over a `ParamGrid` varying **only context depth**
//!    performs exactly one place and one route per `(kernel, seed)`
//!    (asserted via `CacheStats`), because placement and routing read only
//!    the fabric ([`windmill::arch::WindMillParams::topology_hash`]).
//! 2. The resulting `SweepReport` is **bit-identical** to a run with stage
//!    memoization disabled (the monolithic `compile_timed` path) *and* to
//!    the cache-free single-job pipeline — staged assembly is the same
//!    pure function, only sourced differently.
//! 3. Stage artifacts persist: a cold cache on a warm store reuses
//!    place/route from **disk** for a context depth the store has never
//!    seen, recomputing only schedule analysis and config generation.

use std::sync::Arc;

use windmill::arch::isa::OpClass;
use windmill::arch::params::ParamGrid;
use windmill::arch::presets;
use windmill::compiler::{compile, placement_signature, Dfg};
use windmill::coordinator::sweep::DEFAULT_SWEEP_SEED;
use windmill::coordinator::{
    run_job, ArtifactCache, JobSpec, PassCounts, SweepEngine, SweepReport, Workload,
};
use windmill::store::DiskStore;
use windmill::workloads::linalg;

fn ctx_grid() -> ParamGrid {
    // All depths at or above the standard 32, so every kernel that maps on
    // the standard preset maps at every grid point (the context-capacity
    // check only relaxes as depth grows).
    ParamGrid::new(presets::standard()).context_depths(&[32, 48, 64, 128])
}

/// Acceptance criterion: one place + one route per `(dfg, seed)` on a
/// context-depth-only grid, observable through the per-stage cache rows.
#[test]
fn context_depth_sweep_places_and_routes_exactly_once() {
    // Single worker: stage lookups are sequential, so the miss counts are
    // exact (concurrent cold misses could legitimately duplicate work).
    let engine = SweepEngine::new(1);
    let wl = Workload::Saxpy { n: 64 };
    let r = engine.sweep(&ctx_grid(), &wl);
    assert!(r.failures.is_empty(), "{:?}", r.failures);
    assert_eq!(r.points.len(), 4);

    let n = r.points.len() as u64;
    assert_eq!(
        r.cache.pass_counts_full("place"),
        PassCounts { mem: n - 1, disk: 0, miss: 1 },
        "{:?}",
        r.cache
    );
    assert_eq!(
        r.cache.pass_counts_full("route"),
        PassCounts { mem: n - 1, disk: 0, miss: 1 },
        "{:?}",
        r.cache
    );
    // Schedule reads context depth: keyed by the full arch hash, it must
    // recompute at every point — as must the mapping assembly.
    assert_eq!(r.cache.pass_counts_full("schedule").miss, n, "{:?}", r.cache);
    assert_eq!(r.cache.pass_counts_full("mapping").miss, n, "{:?}", r.cache);
    assert!(r.place_route_reuse() >= (n - 1) as f64 / n as f64 - 1e-9, "{:?}", r.cache);
    // The summary surfaces the stage rows (satellite: observability).
    let s = r.summary();
    assert!(s.contains("place"), "{s}");
    assert!(s.contains("route"), "{s}");
    assert!(s.contains("schedule"), "{s}");
}

/// Acceptance criterion: the staged report is bit-identical to the
/// monolithic one and to the cache-free pipeline.
#[test]
fn staged_sweep_is_bit_identical_to_monolithic_and_uncached() {
    let wl = Workload::Fir { n: 64, taps: 8 };
    let staged = SweepEngine::new(1).sweep(&ctx_grid(), &wl);
    let mono = SweepEngine::with_cache(1, Arc::new(ArtifactCache::new().with_stage_memo(false)))
        .sweep(&ctx_grid(), &wl);
    assert!(staged.failures.is_empty(), "{:?}", staged.failures);
    assert!(mono.failures.is_empty(), "{:?}", mono.failures);

    // Monolithic baseline never consulted a stage tier.
    for pass in ["place", "route", "schedule"] {
        assert_eq!(mono.cache.pass_counts_full(pass).lookups(), 0, "{pass}");
    }

    let key = |r: &SweepReport| -> Vec<(String, u64, u64, u64, u64, u32)> {
        r.points
            .iter()
            .map(|p| {
                (
                    p.label.clone(),
                    p.cycles,
                    p.wm_time_ns.to_bits(),
                    p.speedup_vs_cpu.to_bits(),
                    p.area_mm2.to_bits(),
                    p.ii,
                )
            })
            .collect()
    };
    assert_eq!(key(&staged), key(&mono), "staged vs monolithic");
    assert_eq!(staged.frontier, mono.frontier);

    // And against the cache-free single-job pipeline, point by point.
    for (label, params) in ctx_grid().points() {
        let single =
            run_job(&JobSpec { workload: wl.clone(), params, seed: DEFAULT_SWEEP_SEED }).unwrap();
        let p = staged
            .points
            .iter()
            .find(|p| p.label == label)
            .unwrap_or_else(|| panic!("missing point `{label}`"));
        assert_eq!(p.cycles, single.cycles, "{label}");
        assert_eq!(p.wm_time_ns.to_bits(), single.wm_time_ns.to_bits(), "{label}");
        assert_eq!(p.ii, single.ii, "{label}");
    }
}

/// Stage artifacts are persistent: a fresh cache on a warm store
/// warm-starts place/route from **disk** for a context depth whose full
/// mapping entry the store has never seen.
#[test]
fn stage_artifacts_warm_start_from_disk_for_new_context_depths() {
    let dir = std::env::temp_dir()
        .join(format!("windmill-stage-disk-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(DiskStore::open(&dir).unwrap());
    let (dfg, _) = linalg::saxpy(64, 2.0);

    // "Process 1": compile at the standard context depth, populating the
    // place/route/schedule/mapping entries on disk.
    let a = presets::standard();
    let c1 = ArtifactCache::new().with_store(Arc::clone(&store));
    let e1 = c1.machine(&a).unwrap();
    c1.mapping(&a, &dfg, &e1.machine, 7).unwrap();
    assert_eq!(c1.stats().pass_counts_full("place").miss, 1);

    // "Process 2": cold memory, warm store, *different* context depth —
    // the mapping tier misses (new arch hash) but place/route answer from
    // the disk tier; only schedule + config generation recompute.
    let mut b = presets::standard();
    b.context_depth = 64;
    let c2 = ArtifactCache::new().with_store(Arc::clone(&store));
    let e2 = c2.machine(&b).unwrap();
    let (m, _, hit) = c2.mapping(&b, &dfg, &e2.machine, 7).unwrap();
    assert!(!hit, "new context depth cannot hit the mapping tier");
    let s = c2.stats();
    assert_eq!(
        s.pass_counts_full("place"),
        PassCounts { mem: 0, disk: 1, miss: 0 },
        "{s:?}"
    );
    assert_eq!(
        s.pass_counts_full("route"),
        PassCounts { mem: 0, disk: 1, miss: 0 },
        "{s:?}"
    );
    assert_eq!(s.pass_counts_full("schedule").miss, 1, "{s:?}");
    assert_eq!(s.pass_counts_full("mapping").miss, 1, "{s:?}");

    // The disk-assembled mapping equals a from-scratch compile bit for bit.
    let direct = compile(dfg.clone(), &e2.machine, 7).unwrap();
    assert_eq!(m.place, direct.place);
    assert_eq!(m.routes.edges, direct.routes.edges);
    assert_eq!(m.routes.through_load, direct.routes.through_load);
    assert_eq!(m.schedule, direct.schedule);
    assert_eq!(m.config.total_words(), direct.config.total_words());

    // A third cache at depth 64 is now fully warm at the mapping tier.
    let c3 = ArtifactCache::new().with_store(Arc::clone(&store));
    let e3 = c3.machine(&b).unwrap();
    let (_, _, hit3) = c3.mapping(&b, &dfg, &e3.machine, 7).unwrap();
    assert!(hit3, "the staged build was persisted as a full mapping too");
    let _ = std::fs::remove_dir_all(&dir);
}

/// PR 6 acceptance criterion: a seed sweep performs **strictly fewer**
/// Place/Route computations under seed canonicalization, with bit-identical
/// mappings. Deterministic by pigeonhole, no annealer luck involved: a
/// 2-node all-Mem kernel has at most `L·(L-1)` ordered placements over the
/// `L` Mem-capable PEs of `presets::small()`, so sweeping `L·(L-1) + 1`
/// seeds guarantees at least two seeds share a placement-equivalence class.
#[test]
fn seed_sweep_collapses_placement_classes_by_pigeonhole() {
    let params = presets::small();
    let canon = ArtifactCache::new();
    let raw = ArtifactCache::new().with_seed_canon(false);
    let e = canon.machine(&params).unwrap();
    let er = raw.machine(&params).unwrap();
    let l = e.machine.pes_with(OpClass::Mem).len() as u64;

    // load -> store: two nodes, both requiring a Mem-capable PE.
    let mut d = Dfg::new("pair", vec![8]);
    let x = d.load_affine(0, vec![1]);
    d.store_affine(x, 16, vec![1], 1);
    d.validate().unwrap();

    let seeds: Vec<u64> = (0..=l * (l - 1)).collect();
    let mut sigs = std::collections::HashSet::new();
    for &seed in &seeds {
        let (a, _, _) = canon.mapping(&params, &d, &e.machine, seed).unwrap();
        let (b, _, _) = raw.mapping(&params, &d, &er.machine, seed).unwrap();
        // Canonicalization must not change what any seed compiles to.
        assert_eq!(a.place, b.place, "seed {seed}");
        assert_eq!(a.routes.edges, b.routes.edges, "seed {seed}");
        assert_eq!(a.schedule, b.schedule, "seed {seed}");
        assert_eq!(a.config.total_words(), b.config.total_words(), "seed {seed}");
        sigs.insert(placement_signature(&a.place));
    }
    let distinct = sigs.len() as u64;
    assert!(
        distinct < seeds.len() as u64,
        "pigeonhole violated: {distinct} classes from {} seeds over {l} Mem PEs",
        seeds.len()
    );

    // Canonicalized tiers: one Place/Route/Schedule computation per
    // equivalence class; one class probe per raw seed.
    let cs = canon.stats();
    assert_eq!(cs.pass_counts_full("place").miss, distinct, "{cs:?}");
    assert_eq!(cs.pass_counts_full("route").miss, distinct, "{cs:?}");
    assert_eq!(cs.pass_counts_full("schedule").miss, distinct, "{cs:?}");
    assert_eq!(cs.pass_counts_full("seed_class").miss, seeds.len() as u64, "{cs:?}");
    assert_eq!(
        cs.pass_counts_full("place").mem,
        seeds.len() as u64 - distinct,
        "every non-representative seed answers from its class entry: {cs:?}"
    );

    // Raw tiers: one of each per seed — strictly more than the
    // canonicalized cache did.
    let rs = raw.stats();
    assert_eq!(rs.pass_counts_full("place").miss, seeds.len() as u64, "{rs:?}");
    assert_eq!(rs.pass_counts_full("route").miss, seeds.len() as u64, "{rs:?}");
    assert_eq!(rs.pass_counts_full("seed_class").lookups(), 0, "{rs:?}");
}

/// `windmill store gc` smoke at the library level: after a persistent
/// sweep, gc keeps every fresh entry; with a zero byte cap it clears the
/// artifact tiers and the next sweep recomputes and re-persists.
#[test]
fn store_gc_keeps_fresh_entries_and_enforces_caps_between_sweeps() {
    let dir = std::env::temp_dir()
        .join(format!("windmill-stage-gc-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(DiskStore::open(&dir).unwrap());
    let wl = Workload::Saxpy { n: 64 };
    let grid = ParamGrid::new(presets::standard()).context_depths(&[16, 32]);

    let engine = SweepEngine::with_store(1, Arc::clone(&store));
    let cold = engine.sweep(&grid, &wl);
    assert!(cold.failures.is_empty());

    let report = store.gc(None).unwrap();
    assert_eq!(report.stale(), 0, "{report:?}");
    assert!(report.kept() > 0);
    // Per-pass rows exist for the stage directories too.
    for pass in ["place", "route", "schedule", "mapping", "simulate", "elaborate"] {
        assert!(
            report.passes.iter().any(|p| p.pass == pass && p.kept > 0),
            "missing gc row for `{pass}`: {report:?}"
        );
    }

    let wiped = store.gc(Some(0)).unwrap();
    assert_eq!(wiped.kept(), 0, "{wiped:?}");
    assert!(wiped.evicted() > 0);

    // A fresh engine on the emptied store recomputes — and the results
    // match the pre-gc sweep exactly.
    let engine2 = SweepEngine::with_store(1, Arc::clone(&store));
    let again = engine2.sweep(&grid, &wl);
    assert!(again.failures.is_empty());
    assert_eq!(again.cache.misses, cold.cache.misses, "fully cold again");
    let key = |r: &SweepReport| -> Vec<(String, u64)> {
        r.points.iter().map(|p| (p.label.clone(), p.cycles)).collect()
    };
    assert_eq!(key(&cold), key(&again));
    let _ = std::fs::remove_dir_all(&dir);
}
