//! Full-stack integration tests: AOT artifacts (PJRT) ↔ cycle-accurate
//! simulator ↔ analytic models, plus the paper's §V anchors.

use windmill::arch::params::ParamGrid;
use windmill::arch::presets;
use windmill::compiler::compile;
use windmill::coordinator::{
    calibrate_params, ppa_report, run_job, JobSpec, SweepEngine, Workload,
};
use windmill::netlist::verilog;
use windmill::plugins;
use windmill::runtime::Runtime;
use windmill::sim::task::{run_task, Phase, Task};
use windmill::workloads::rl;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// The headline cross-layer check: one REINFORCE step executed (a) by the
/// AOT'd JAX/Pallas graph through PJRT and (b) by the cycle-accurate
/// simulator on the generated WindMill — same parameters, same batch —
/// must agree on every updated weight and the loss.
#[test]
fn rl_step_simulator_matches_pjrt_golden() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // Without the `pjrt` feature the stub runtime cannot load: skip, don't
    // panic (artifacts may exist on a box that can't execute them).
    let Ok(mut rt) = Runtime::load(artifacts_dir()) else {
        eprintln!("skipping: PJRT runtime unavailable (build with --features pjrt)");
        return;
    };

    let step = rl::policy_step();
    let params = calibrate_params(presets::standard(), &step.layout);
    let machine = plugins::elaborate(params).unwrap().artifact;
    let mem = rl::init_image(&step, 99, machine.smem.as_ref().unwrap().words());
    let l = &step.layout;

    // PJRT side.
    let inputs: Vec<Vec<f32>> = ["w1", "b1", "w2", "b2", "obs", "onehot", "returns"]
        .iter()
        .map(|name| l.read(&mem, name).to_vec())
        .collect();
    let golden = rt.execute("policy_step", &inputs).unwrap();

    // Simulator side.
    let n = step.phases.len();
    let task = Task {
        name: "rl".into(),
        phases: step
            .phases
            .iter()
            .enumerate()
            .map(|(i, d)| Phase {
                mapping: std::sync::Arc::new(compile(d.clone(), &machine, 42).unwrap()),
                dma_in_words: if i == 0 { 500 } else { 0 },
                dma_out_words: if i + 1 == n { 1 } else { 0 },
            })
            .collect(),
    };
    let tr = run_task(&task, &machine, &mem, 8_000_000).unwrap();

    for (idx, name) in ["w1", "b1", "w2", "b2"].iter().enumerate() {
        let sim = l.read(&tr.mem, name);
        let gold = &golden[idx];
        assert_eq!(sim.len(), gold.len(), "{name}");
        for (i, (a, b)) in sim.iter().zip(gold.iter()).enumerate() {
            assert!((a - b).abs() < 1e-4, "{name}[{i}]: sim {a} vs pjrt {b}");
        }
    }
    let sim_loss = l.read(&tr.mem, "loss")[0];
    assert!((sim_loss - golden[4][0]).abs() < 1e-4, "loss {sim_loss} vs {}", golden[4][0]);
    assert!(tr.total_cycles > 1000);
}

/// All five artifacts execute through PJRT with manifest-consistent shapes.
#[test]
fn all_artifacts_execute() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let Ok(mut rt) = Runtime::load(artifacts_dir()) else {
        eprintln!("skipping: PJRT runtime unavailable (build with --features pjrt)");
        return;
    };
    let names: Vec<String> = rt.manifest.entries.iter().map(|e| e.name.clone()).collect();
    assert_eq!(names.len(), 5);
    for name in names {
        let spec = rt.manifest.entry(&name).unwrap().clone();
        let inputs: Vec<Vec<f32>> = spec
            .inputs
            .iter()
            .map(|t| (0..t.elements()).map(|i| (i % 13) as f32 * 0.1 - 0.5).collect())
            .collect();
        let out = rt.execute(&name, &inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(out.len(), spec.outputs.len(), "{name}");
        for (o, t) in out.iter().zip(&spec.outputs) {
            assert_eq!(o.len(), t.elements(), "{name}");
            assert!(o.iter().all(|x| x.is_finite()), "{name} produced non-finite values");
        }
    }
}

/// Paper §V anchor: the standard instance runs at 750 MHz and ~16.15 mW.
#[test]
fn ppa_anchors_hold() {
    let r = ppa_report("standard", presets::standard()).unwrap();
    assert!(r.fmax_mhz >= 750.0, "timing does not close at 750 MHz: {:.0}", r.fmax_mhz);
    assert!(
        (r.power_mw - 16.15).abs() < 4.0,
        "power {:.2} mW drifted from the 16.15 mW anchor",
        r.power_mw
    );
}

/// The paper's headline ratios, end to end through the coordinator.
#[test]
fn rl_speedups_are_paper_shaped() {
    let r = run_job(&JobSpec {
        workload: Workload::RlStep,
        params: presets::standard(),
        seed: 42,
    })
    .unwrap();
    // "average 200x compared to CPU" — same decade, spatial win.
    assert!(
        r.speedup_vs_cpu > 100.0 && r.speedup_vs_cpu < 400.0,
        "vs CPU: {:.0}x",
        r.speedup_vs_cpu
    );
    // "2.3x compared to GPU" — a small-factor win.
    assert!(
        r.speedup_vs_gpu > 1.5 && r.speedup_vs_gpu < 4.0,
        "vs GPU: {:.2}x",
        r.speedup_vs_gpu
    );
}

/// Unplug → elaborate → re-plug regenerates byte-identical Verilog, with
/// zero residue while detached (the Fig. 3 / Fig. 6d agility claim).
#[test]
fn unplug_replug_verilog_stability() {
    let mut gen = plugins::generator(presets::standard());
    let base = verilog::emit(&gen.elaborate().unwrap().netlist);

    gen.unplug("fu-sfu");
    gen.params_mut().sfu_enabled = false;
    let without = gen.elaborate().unwrap();
    assert!(without.netlist.find("fu_sfu").is_none());
    assert!(verilog::emit(&without.netlist).len() < base.len());

    gen.params_mut().sfu_enabled = true;
    gen.plug(Box::new(plugins::fu::SfuFuPlugin)).unwrap();
    let restored = verilog::emit(&gen.elaborate().unwrap().netlist);
    assert_eq!(restored, base);
}

/// Cross-domain suite: every workload domain runs and beats the host CPU.
#[test]
fn cross_domain_suite_beats_host_cpu() {
    for workload in [
        Workload::Saxpy { n: 128 },
        Workload::Fir { n: 128, taps: 8 },
        Workload::Conv3x3 { h: 16, w: 16 },
    ] {
        let r = run_job(&JobSpec { workload, params: presets::standard(), seed: 5 })
            .unwrap();
        assert!(r.speedup_vs_cpu > 1.0, "{}: {:.2}x", r.name, r.speedup_vs_cpu);
    }
}

/// The sweep engine end to end: a Fig. 6-style grid on a fixed workload
/// must (a) match uncached single-point runs bit-for-bit, (b) produce a
/// non-empty best-PPA frontier, and (c) answer a warm re-run from the
/// artifact cache.
#[test]
fn sweep_engine_matches_single_runs_and_caches() {
    let engine = SweepEngine::new(2);
    let grid = ParamGrid::new(presets::standard()).pea_edges(&[4, 8]);
    let workload = Workload::Saxpy { n: 128 };

    let report = engine.sweep(&grid, &workload);
    assert!(report.failures.is_empty(), "{:?}", report.failures);
    assert_eq!(report.points.len(), 2);
    assert!(!report.frontier.is_empty());

    // Every sweep point agrees with the uncached single-shot pipeline.
    for (label, params) in grid.points() {
        let single = run_job(&JobSpec {
            workload: workload.clone(),
            params,
            seed: windmill::coordinator::sweep::DEFAULT_SWEEP_SEED,
        })
        .unwrap();
        let point = report
            .points
            .iter()
            .find(|p| p.label == label)
            .unwrap_or_else(|| panic!("missing point `{label}`"));
        assert_eq!(point.cycles, single.cycles, "{label}");
        assert_eq!(point.ii, single.ii, "{label}");
    }

    // Warm re-run: all hits, same numbers.
    let warm = engine.sweep(&grid, &workload);
    assert!(warm.cache_hit_rate() > 0.99, "{:?}", warm.cache);
    assert_eq!(warm.points.len(), report.points.len());
}
