//! Property-based invariants over randomly generated DFGs (proptest is not
//! vendored; this is a seeded random-program generator with the same
//! spirit: many random cases, shrink-by-reporting-seed).
//!
//! Invariants checked for every random kernel:
//!  * placement is exclusive and capability-legal,
//!  * every routed path walks topology-adjacent PEs from producer to
//!    consumer,
//!  * generated config words survive encode/decode bit-exactly,
//!  * the cycle-accurate simulator's memory image matches the sequential
//!    reference interpreter's exactly,
//!  * compilation and simulation are deterministic for a fixed seed.

use windmill::arch::isa::Op;
use windmill::arch::presets;
use windmill::compiler::{compile, dfg::interpret, Dfg};
use windmill::plugins;
use windmill::sim::engine::simulate;
use windmill::sim::MachineDesc;
use windmill::util::Rng;

/// Random small DFG: a layered acyclic graph over one loop dimension with
/// affine loads, arithmetic, optional accumulator, and a store.
fn random_dfg(rng: &mut Rng, case: usize) -> Dfg {
    let iters = *rng.choose(&[4u32, 8, 16, 32]);
    let mut d = Dfg::new(&format!("prop-{case}"), vec![iters]);
    let n_loads = rng.range(1, 4);
    let mut values = Vec::new();
    for i in 0..n_loads {
        values.push(d.load_affine((i as u32) * 64, vec![1]));
    }
    let n_ops = rng.range(1, 7);
    let binops = [Op::Add, Op::Sub, Op::Mul, Op::Min, Op::Max];
    let unops = [Op::Abs, Op::Neg, Op::Tanh, Op::Sqrt];
    for _ in 0..n_ops {
        let v = if rng.bool(0.65) && values.len() >= 2 {
            let a = *rng.choose(&values);
            let b = *rng.choose(&values);
            d.compute(*rng.choose(&binops), a, b)
        } else {
            let a = *rng.choose(&values);
            // Sqrt of negatives -> NaN is fine (compared as NaN==NaN below),
            // but keep values tame with Abs first half the time.
            if rng.bool(0.5) {
                let abs = d.unary(Op::Abs, a);
                d.unary(*rng.choose(&unops), abs)
            } else {
                d.unary(*rng.choose(&[Op::Abs, Op::Neg, Op::Tanh]), a)
            }
        };
        values.push(v);
    }
    let last = *values.last().unwrap();
    if rng.bool(0.4) {
        let acc = d.accum(Op::Add, last, 0.0, iters);
        d.store_affine(acc, 512, vec![0], iters);
    } else {
        d.store_affine(last, 512, vec![1], 1);
    }
    d
}

fn machine() -> MachineDesc {
    plugins::elaborate(presets::standard()).unwrap().artifact
}

fn close(a: f32, b: f32) -> bool {
    (a - b).abs() < 1e-5 || (a.is_nan() && b.is_nan())
}

#[test]
fn random_kernels_simulate_exactly_like_the_interpreter() {
    let m = machine();
    let words = m.smem.as_ref().unwrap().words();
    for case in 0..40usize {
        let mut rng = Rng::new(1000 + case as u64);
        let d = random_dfg(&mut rng, case);
        d.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));

        let mut image = vec![0.0f32; words];
        for x in image.iter_mut().take(256) {
            *x = rng.normal();
        }
        let mut golden = image.clone();
        interpret(&d, &mut golden).unwrap_or_else(|e| panic!("case {case}: {e}"));

        let mapping = compile(d, &m, 7).unwrap_or_else(|e| panic!("case {case}: {e}"));
        let res = simulate(&mapping, &m, &image, 2_000_000)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        for (i, (a, b)) in res.mem.iter().zip(golden.iter()).enumerate() {
            assert!(close(*a, *b), "case {case} mem[{i}]: sim {a} vs golden {b}");
        }
    }
}

#[test]
fn random_placements_are_legal_and_routes_adjacent() {
    let m = machine();
    let topo = m.topology.unwrap();
    for case in 0..60usize {
        let mut rng = Rng::new(5000 + case as u64);
        let d = random_dfg(&mut rng, case);
        let mapping = compile(d, &m, case as u64).unwrap();

        // Exclusive, legal placement.
        let mut used = std::collections::HashSet::new();
        for (i, &(r, c)) in mapping.place.iter().enumerate() {
            assert!(used.insert((r, c)), "case {case}: PE ({r},{c}) reused");
            let class = windmill::compiler::place::required_class(&mapping.dfg, i);
            assert!(
                m.pe(r, c).caps.contains(&class),
                "case {case}: node {i} needs {class:?} on {:?}",
                m.pe(r, c).ty
            );
        }
        // Adjacent routes with correct endpoints.
        for e in &mapping.routes.edges {
            assert_eq!(e.path[0], mapping.place[e.src_node], "case {case}");
            assert_eq!(*e.path.last().unwrap(), mapping.place[e.dst_node], "case {case}");
            for w in e.path.windows(2) {
                assert!(
                    topo.neighbors(w[0].0, w[0].1, m.rows, m.cols)
                        .iter()
                        .any(|(n, _)| *n == w[1]),
                    "case {case}: non-adjacent hop {:?}->{:?}",
                    w[0],
                    w[1]
                );
            }
        }
        // Config words roundtrip.
        for ws in mapping.config.words.values() {
            for w in ws {
                let back = windmill::arch::isa::ConfigWord::decode(w.encode()).unwrap();
                assert_eq!(*w, back, "case {case}");
            }
        }
        // Schedule sanity.
        assert!(mapping.schedule.ii >= 1);
        assert!(mapping.schedule.ctx_words_needed >= 1);
    }
}

#[test]
fn compilation_is_deterministic_across_runs() {
    let m = machine();
    for case in 0..10usize {
        let mut r1 = Rng::new(9000 + case as u64);
        let mut r2 = Rng::new(9000 + case as u64);
        let d1 = random_dfg(&mut r1, case);
        let d2 = random_dfg(&mut r2, case);
        let m1 = compile(d1, &m, 3).unwrap();
        let m2 = compile(d2, &m, 3).unwrap();
        assert_eq!(m1.place, m2.place, "case {case}");
        assert_eq!(m1.schedule, m2.schedule, "case {case}");
        assert_eq!(m1.routes.total_hops(), m2.routes.total_hops(), "case {case}");
    }
}

#[test]
fn elaboration_is_deterministic_and_valid_across_param_space() {
    let mut rng = Rng::new(77);
    for case in 0..20usize {
        let mut p = presets::standard();
        let edge = *rng.choose(&[3usize, 4, 5, 8, 10]);
        p.rows = edge;
        p.cols = edge;
        p.topology = *rng.choose(&[
            windmill::arch::Topology::Mesh2D,
            windmill::arch::Topology::OneHop,
            windmill::arch::Topology::Torus,
        ]);
        p.sfu_enabled = rng.bool(0.7);
        p.cpe_enabled = rng.bool(0.7) && edge >= 3;
        p.pingpong = rng.bool(0.7);
        p.rca_count = rng.range(1, 5);
        if p.validate().is_err() {
            continue;
        }
        let a = plugins::elaborate(p.clone()).unwrap_or_else(|e| panic!("case {case}: {e}"));
        let b = plugins::elaborate(p).unwrap();
        a.netlist.validate().unwrap();
        a.artifact.validate().unwrap();
        assert_eq!(
            windmill::netlist::verilog::emit(&a.netlist),
            windmill::netlist::verilog::emit(&b.netlist),
            "case {case}: nondeterministic emission"
        );
    }
}

#[test]
fn area_model_is_monotone_in_pea_size() {
    let mut last = 0.0;
    for edge in [4usize, 6, 8, 10, 12, 16] {
        let e = plugins::elaborate(presets::with_pea_size(edge)).unwrap();
        let s = windmill::netlist::NetlistStats::of(&e.netlist);
        assert!(s.total_gates > last, "area not monotone at {edge}");
        last = s.total_gates;
    }
}

#[test]
fn random_two_phase_tasks_chain_memory_like_the_interpreter() {
    // Multi-phase coverage: phase 2 consumes phase 1's output region; the
    // task runner's memory chaining must agree with sequential
    // interpretation of both DFGs.
    use windmill::sim::task::{run_task, Phase, Task};
    let m = machine();
    let words = m.smem.as_ref().unwrap().words();
    for case in 0..12usize {
        let mut rng = Rng::new(20_000 + case as u64);
        let iters = *rng.choose(&[8u32, 16, 32]);
        // Phase 1: out1[i] = |x[i]| * c.
        let mut d1 = Dfg::new("p1", vec![iters]);
        let x = d1.load_affine(0, vec![1]);
        let a = d1.unary(Op::Abs, x);
        let c = d1.constant(rng.f32() + 0.5);
        let y = d1.compute(Op::Mul, a, c);
        d1.store_affine(y, 1024, vec![1], 1);
        // Phase 2: out2[i] = tanh(out1[i]) + out1[i].
        let mut d2 = Dfg::new("p2", vec![iters]);
        let z = d2.load_affine(1024, vec![1]);
        let t = d2.unary(Op::Tanh, z);
        let s = d2.compute(Op::Add, t, z);
        d2.store_affine(s, 2048, vec![1], 1);

        let mut image = vec![0.0f32; words];
        for w in image.iter_mut().take(64) {
            *w = rng.normal();
        }
        let mut golden = image.clone();
        interpret(&d1, &mut golden).unwrap();
        interpret(&d2, &mut golden).unwrap();

        let task = Task {
            name: format!("chain-{case}"),
            phases: vec![
                Phase {
                    mapping: std::sync::Arc::new(compile(d1, &m, 3).unwrap()),
                    dma_in_words: 64,
                    dma_out_words: 0,
                },
                Phase {
                    mapping: std::sync::Arc::new(compile(d2, &m, 3).unwrap()),
                    dma_in_words: 0,
                    dma_out_words: iters as u64,
                },
            ],
        };
        let tr = run_task(&task, &m, &image, 2_000_000).unwrap();
        for (i, (a, b)) in tr.mem.iter().zip(golden.iter()).enumerate() {
            assert!(close(*a, *b), "case {case} mem[{i}]: {a} vs {b}");
        }
        // Timing structure sanity.
        assert_eq!(tr.phase_compute.len(), 2);
        assert!(tr.total_cycles >= tr.compute_cycles);
        assert!(tr.dma_cycles_total >= tr.dma_cycles_exposed);
    }
}

#[test]
fn simulator_cycle_counts_are_seed_stable() {
    // Same mapping + image -> identical cycle count and stats across runs.
    let m = machine();
    let words = m.smem.as_ref().unwrap().words();
    let mut rng = Rng::new(31);
    let d = random_dfg(&mut rng, 0);
    let mapping = compile(d, &m, 11).unwrap();
    let image = vec![0.5f32; words];
    let a = simulate(&mapping, &m, &image, 2_000_000).unwrap();
    let b = simulate(&mapping, &m, &image, 2_000_000).unwrap();
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.fires, b.fires);
    assert_eq!(a.smem, b.smem);
}
