//! Mutation tests for the static analyzer (PR 10): every corruption of a
//! valid compiled artifact must be caught with its exact `WM####` code
//! before a single cycle is simulated, every shipped workload × preset
//! must check clean, the engine's empty-calendar deadlock must carry the
//! code the analyzer predicts statically, and the whole checker must be
//! panic-free on randomized garbage.

use windmill::analysis::{self, Severity};
use windmill::arch::isa::{Op, OpClass};
use windmill::arch::presets;
use windmill::compiler::{compile, Dfg, Mapping, Node, NodeKind};
use windmill::coordinator::{calibrate_params, run_job, JobSpec, Workload};
use windmill::plugins;
use windmill::sim::engine::simulate;
use windmill::sim::machine::MachineDesc;
use windmill::util::Rng;

fn std_machine() -> MachineDesc {
    plugins::elaborate(presets::standard()).unwrap().artifact
}

/// A small but route-rich kernel: the FIR tap chain spreads across PEs,
/// so its mapping carries multi-hop routes to corrupt.
fn mapped_fir(machine: &MachineDesc, seed: u64) -> Mapping {
    let (dfgs, _layout) = Workload::Fir { n: 64, taps: 6 }.build();
    let dfg = dfgs.into_iter().next().unwrap();
    compile(dfg, machine, seed).unwrap()
}

fn codes(diags: &[analysis::Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.code).collect()
}

#[test]
fn compiled_mapping_is_clean_and_bounded() {
    let machine = std_machine();
    let mapping = mapped_fir(&machine, 42);
    let diags = analysis::check(&mapping, &machine);
    assert!(diags.is_empty(), "healthy artifact flagged: {diags:?}");
    let bound = analysis::cycles_lower_bound(&mapping, &machine);
    assert!(bound > 0, "nonzero kernel must have a nonzero bound");
    // The oracle: the simulator can never beat the static bound.
    let words = machine.smem.as_ref().unwrap().words();
    let res = simulate(&mapping, &machine, &vec![0.5f32; words], 10_000_000).unwrap();
    assert!(
        bound <= res.cycles,
        "bound {bound} exceeds simulated {} cycles",
        res.cycles
    );
}

#[test]
fn truncated_placement_is_wm0101() {
    let machine = std_machine();
    let mut mapping = mapped_fir(&machine, 42);
    mapping.place.pop();
    let diags = analysis::check(&mapping, &machine);
    assert!(codes(&diags).contains(&"WM0101"), "{diags:?}");
    assert!(analysis::has_errors(&diags));
}

#[test]
fn out_of_fabric_placement_is_wm0102() {
    let machine = std_machine();
    let mut mapping = mapped_fir(&machine, 42);
    mapping.place[0] = (machine.rows + 3, 0);
    let diags = analysis::check(&mapping, &machine);
    assert!(codes(&diags).contains(&"WM0102"), "{diags:?}");
}

#[test]
fn duplicate_placement_is_wm0103() {
    let machine = std_machine();
    let mut mapping = mapped_fir(&machine, 42);
    mapping.place[1] = mapping.place[0];
    let diags = analysis::check(&mapping, &machine);
    assert!(codes(&diags).contains(&"WM0103"), "{diags:?}");
}

#[test]
fn capability_mismatch_is_wm0104() {
    let machine = std_machine();
    let mut mapping = mapped_fir(&machine, 42);
    // Move a memory node onto a PE that cannot execute Mem ops.
    let load = mapping.dfg.loads()[0];
    let gpe = (0..machine.rows)
        .flat_map(|r| (0..machine.cols).map(move |c| (r, c)))
        .find(|&(r, c)| !machine.pe(r, c).caps.contains(&OpClass::Mem))
        .expect("standard fabric has non-memory PEs");
    mapping.place[load] = gpe;
    let diags = analysis::check(&mapping, &machine);
    assert!(codes(&diags).contains(&"WM0104"), "{diags:?}");
}

#[test]
fn severed_route_is_wm0105() {
    let machine = std_machine();
    let mut mapping = mapped_fir(&machine, 42);
    // Drop the route of some cross-PE edge (path length >= 2).
    let pos = mapping
        .routes
        .edges
        .iter()
        .position(|r| r.path.len() >= 2)
        .expect("fir mapping has cross-PE routes");
    mapping.routes.edges.remove(pos);
    let diags = analysis::check(&mapping, &machine);
    assert!(codes(&diags).contains(&"WM0105"), "{diags:?}");
}

#[test]
fn route_endpoint_mismatch_is_wm0106() {
    let machine = std_machine();
    let mut mapping = mapped_fir(&machine, 42);
    let route = mapping
        .routes
        .edges
        .iter_mut()
        .find(|r| r.path.len() >= 2)
        .expect("fir mapping has cross-PE routes");
    // Retarget the head of the path away from the producer's PE.
    let head = route.path[0];
    route.path[0] = ((head.0 + 1) % 8, (head.1 + 1) % 8);
    let diags = analysis::check(&mapping, &machine);
    assert!(
        codes(&diags).contains(&"WM0106") || codes(&diags).contains(&"WM0107"),
        "{diags:?}"
    );
    assert!(analysis::has_errors(&diags));
}

#[test]
fn teleporting_route_hop_is_wm0107() {
    let machine = std_machine();
    let mut mapping = mapped_fir(&machine, 42);
    let route = mapping
        .routes
        .edges
        .iter_mut()
        .find(|r| r.path.len() >= 2)
        .expect("fir mapping has cross-PE routes");
    // Insert an interior hop 3+3 Manhattan away from its predecessor —
    // no mesh2d neighbour relation can cover that jump.
    let head = route.path[0];
    let far = ((head.0 + 3) % machine.rows, (head.1 + 3) % machine.cols);
    route.path.insert(1, far);
    let diags = analysis::check(&mapping, &machine);
    assert!(codes(&diags).contains(&"WM0107"), "{diags:?}");
}

#[test]
fn undersized_ii_is_wm0108() {
    let machine = std_machine();
    let mut mapping = mapped_fir(&machine, 42);
    mapping.schedule.ii = 0;
    let diags = analysis::check(&mapping, &machine);
    assert!(codes(&diags).contains(&"WM0108"), "{diags:?}");
}

#[test]
fn context_overflow_is_wm0109() {
    let mut machine = std_machine();
    let mapping = mapped_fir(&machine, 42);
    // Shrink the fabric's context memory under the mapping's footprint.
    machine.context_depth = 0;
    let diags = analysis::check(&mapping, &machine);
    assert!(codes(&diags).contains(&"WM0109"), "{diags:?}");
}

#[test]
fn smem_overallocation_is_wm0110() {
    let machine = std_machine();
    let mut mapping = mapped_fir(&machine, 42);
    let words = machine.smem.as_ref().unwrap().words() as u32;
    let load = mapping.dfg.loads()[0];
    // Rebase the access one word past the end of shared memory.
    if let NodeKind::Load(windmill::compiler::Access::Affine { base, .. }) =
        &mut mapping.dfg.nodes[load].kind
    {
        *base = words;
    } else {
        panic!("fir load is affine");
    }
    let diags = analysis::check(&mapping, &machine);
    assert!(codes(&diags).contains(&"WM0110"), "{diags:?}");
}

#[test]
fn iteration_tag_overflow_is_wm0301() {
    let mut d = Dfg::new("huge", vec![1 << 16, 1 << 16]);
    let x = d.load_affine(0, vec![1, 0]);
    d.store_affine(x, 8, vec![1, 0], 1);
    let diags = analysis::check_dfg(&d);
    assert!(
        diags.iter().any(|dg| dg.code == "WM0301" && dg.severity == Severity::Error),
        "{diags:?}"
    );
}

#[test]
fn dangling_operand_is_wm0302_and_fan_in_is_wm0303() {
    let mut d = Dfg::new("bad", vec![8]);
    let x = d.load_affine(0, vec![1]);
    let y = d.compute(Op::Add, x, x);
    d.store_affine(y, 16, vec![1], 1);
    d.nodes.push(Node {
        op: Op::Add,
        kind: NodeKind::Compute,
        inputs: vec![99],
        imm: 0.0,
    });
    let diags = analysis::check_dfg(&d);
    assert!(codes(&diags).contains(&"WM0302"), "{diags:?}");

    let mut d3 = Dfg::new("wide", vec![8]);
    let a = d3.load_affine(0, vec![1]);
    d3.nodes.push(Node {
        op: Op::Add,
        kind: NodeKind::Compute,
        inputs: vec![a, a, a],
        imm: 0.0,
    });
    let w = d3.nodes.len() - 1;
    d3.store_affine(w, 16, vec![1], 1);
    let diags = analysis::check_dfg(&d3);
    assert!(codes(&diags).contains(&"WM0303"), "{diags:?}");
}

/// The kernel behind the empty-calendar deadlock: a compute node fed by a
/// store. Stores broadcast nothing, so the second store is token-starved.
/// Passes `Dfg::validate` and compiles — only the analyzer (statically)
/// and the engine (dynamically, with the same code) reject it.
fn deadlock_kernel() -> Dfg {
    let mut d = Dfg::new("store-fed", vec![16]);
    let x = d.load_affine(0, vec![1]);
    let s = d.store_affine(x, 64, vec![1], 1);
    let y = d.compute(Op::Add, s, s);
    d.store_affine(y, 128, vec![1], 1);
    d
}

#[test]
fn deadlock_prediction_matches_engine_diagnosis() {
    let machine = std_machine();
    let d = deadlock_kernel();
    d.validate().expect("structurally valid — that's the point");
    let mapping = compile(d, &machine, 42).unwrap();

    // Static: the hazard pass flags the starved store (WM0201) and the
    // store-sourced operand (WM0202) without running a cycle.
    let diags = analysis::check(&mapping, &machine);
    assert!(codes(&diags).contains(&"WM0201"), "{diags:?}");
    assert!(codes(&diags).contains(&"WM0202"), "{diags:?}");

    // Dynamic: the engine deadlocks on the same kernel, and its error
    // carries the exact code the analyzer predicted.
    let words = machine.smem.as_ref().unwrap().words();
    let err = simulate(&mapping, &machine, &vec![0.5f32; words], 100_000)
        .map(|_| ())
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("WM0201"), "engine error lacks the hazard code: {msg}");
    assert!(msg.contains("deadlock"), "{msg}");
}

#[test]
fn shipped_workloads_and_presets_check_clean() {
    let names = ["saxpy", "dot", "gemm", "spmv", "bfs", "fir", "conv", "rl"];
    let mut checked = 0usize;
    for preset in presets::NAMES {
        let base = presets::by_name(preset).unwrap();
        for wl_name in names {
            let workload = Workload::parse(wl_name).unwrap();
            let (dfgs, layout) = workload.build();
            let params = calibrate_params(base.clone(), &layout);
            let machine = plugins::elaborate(params).unwrap().artifact;
            for dfg in dfgs {
                let name = dfg.name.clone();
                let mapping = match compile(dfg, &machine, 42) {
                    Ok(m) => m,
                    Err(e) => {
                        // A capacity refusal by the mapper is legitimate on
                        // the small preset; on the bigger fabrics every
                        // shipped kernel must map.
                        assert_eq!(
                            preset, "small",
                            "`{wl_name}`/{name} must map on `{preset}`: {e}"
                        );
                        continue;
                    }
                };
                let diags = analysis::check(&mapping, &machine);
                assert!(
                    diags.is_empty(),
                    "`{wl_name}`/{name} on `{preset}` flagged: {diags:?}"
                );
                assert!(analysis::cycles_lower_bound(&mapping, &machine) > 0);
                checked += 1;
            }
        }
    }
    assert!(checked >= 16, "only {checked} workload phases checked");
}

#[test]
fn bound_rides_through_run_job_and_stays_sound() {
    for wl in ["gemm", "fir", "spmv"] {
        let spec = JobSpec {
            workload: Workload::parse(wl).unwrap(),
            params: presets::standard(),
            seed: 42,
        };
        let r = run_job(&spec).unwrap();
        assert!(r.bound > 0, "{wl}: zero bound");
        assert!(
            r.bound <= r.cycles,
            "{wl}: bound {} exceeds simulated {}",
            r.bound,
            r.cycles
        );
    }
}

#[test]
fn fuzzed_corruptions_never_panic_the_checker() {
    let machine = std_machine();
    let mut rng = Rng::new(0xF00D_CAFE);
    for trial in 0..32u64 {
        let mut mapping = mapped_fir(&machine, trial % 5);
        let mut m = machine.clone();
        for _ in 0..(1 + rng.below(4)) {
            match rng.below(7) {
                0 => {
                    let i = rng.below(mapping.place.len() as u64) as usize;
                    mapping.place[i] =
                        (rng.below(12) as usize, rng.below(12) as usize);
                }
                1 => {
                    if !mapping.routes.edges.is_empty() {
                        let i = rng.below(mapping.routes.edges.len() as u64) as usize;
                        let r = &mut mapping.routes.edges[i];
                        let coord = (rng.below(10) as usize, rng.below(10) as usize);
                        let at = rng.below(r.path.len() as u64 + 1) as usize;
                        r.path.insert(at, coord);
                    }
                }
                2 => {
                    if !mapping.routes.edges.is_empty() {
                        let i = rng.below(mapping.routes.edges.len() as u64) as usize;
                        mapping.routes.edges[i].path.clear();
                    }
                }
                3 => mapping.schedule.ii = rng.below(3) as u32,
                4 => m.context_depth = rng.below(4) as usize,
                5 => {
                    let i = rng.below(mapping.dfg.nodes.len() as u64) as usize;
                    mapping.dfg.nodes[i].inputs.push(rng.below(64) as usize);
                }
                _ => {
                    mapping.place.pop();
                }
            }
        }
        // Must terminate without panicking, whatever it finds.
        let _ = analysis::check(&mapping, &m);
    }
}
