//! Telemetry equivalence and accounting suite (EXPERIMENTS.md §Telemetry).
//!
//! Three pins:
//!
//! 1. **Observational invisibility.** A profiled simulation
//!    (`SimOptions::profile`, with or without a sampled activity timeline)
//!    is bit-identical on memory and identical on every timing observable
//!    (cycles, fires, smem stats, skipped-cycle count, derived metrics) to
//!    the unprofiled run — solo through [`simulate_counting_with`] and in
//!    5-lane [`simulate_batch_with`] arenas.
//! 2. **Exact attribution.** For every profiled kernel the stall histogram
//!    satisfies `sum(stalls) == n_nodes * cycles - fires` *exactly* —
//!    every node-cycle is attributed to precisely one outcome, including
//!    event-skipped spans and the end-of-run drain — and the sampled
//!    timeline tiles `[0, cycles]` with the per-row fire counts summing to
//!    the engine's own fire counter.
//! 3. **Codec round-trip.** `TelemetrySummary` survives the store codec
//!    bit-exactly, including counters above 2^53 (which a float-backed
//!    encoding would corrupt).

use windmill::arch::isa::Op;
use windmill::arch::presets;
use windmill::compiler::{compile, Dfg, Mapping};
use windmill::sim::{
    simulate_batch, simulate_batch_with, simulate_counting, simulate_counting_with, LaneSpec,
    MachineDesc, PeActivity, SimOptions, SimResult, StallCause, TelemetrySummary, TimelineSpan,
    STALL_NAMES,
};
use windmill::store::codec::{decode_sim, encode_sim};
use windmill::util::Rng;

fn machine() -> MachineDesc {
    windmill::plugins::elaborate(presets::standard()).unwrap().artifact
}

const BINOPS: [Op; 5] = [Op::Add, Op::Sub, Op::Mul, Op::Min, Op::Max];
const UNOPS: [Op; 4] = [Op::Abs, Op::Neg, Op::Tanh, Op::Add];

/// Randomized kernels cycling through the engine-equivalence shapes:
/// affine pipelines, 2-D accumulator nests, indirect gathers, and
/// stall-heavy SFU chains (the skip-path stressor — telemetry must
/// attribute skipped spans in closed form, not by ticking).
fn random_kernel(rng: &mut Rng, case: usize) -> Dfg {
    match case % 4 {
        0 => {
            let iters = *rng.choose(&[8u32, 16, 32]);
            let mut d = Dfg::new(&format!("tel-affine-{case}"), vec![iters]);
            let a = d.load_affine(0, vec![1]);
            let b = d.load_affine(64, vec![1]);
            let mut v = d.compute(*rng.choose(&BINOPS), a, b);
            for _ in 0..rng.range(1, 4) {
                v = d.unary(*rng.choose(&UNOPS), v);
            }
            d.store_affine(v, 2048, vec![1], 1);
            d
        }
        1 => {
            let outer = *rng.choose(&[2u32, 4, 8]);
            let inner = *rng.choose(&[4u32, 8]);
            let mut d = Dfg::new(&format!("tel-accum-{case}"), vec![outer, inner]);
            let a = d.load_affine(0, vec![inner as i32, 1]);
            let b = d.load_affine(64, vec![0, 1]);
            let v = d.compute(Op::Mul, a, b);
            let acc = d.accum(Op::Add, v, 0.0, inner);
            d.store_affine(acc, 2048, vec![1, 0], inner);
            d
        }
        2 => {
            let iters = *rng.choose(&[8u32, 16, 32]);
            let mut d = Dfg::new(&format!("tel-gather-{case}"), vec![iters]);
            let idx = d.index(0);
            let base = d.constant(1024.0);
            let addr = d.compute(Op::Add, idx, base);
            let x = d.load_indirect(addr);
            let y = d.unary(*rng.choose(&UNOPS), x);
            d.store_affine(y, 2048, vec![1], 1);
            d
        }
        _ => {
            let iters = *rng.choose(&[1u32, 2, 4]);
            let depth = rng.range(3, 8);
            let mut d = Dfg::new(&format!("tel-sfu-{case}"), vec![iters]);
            let mut v = d.load_affine(0, vec![1]);
            for _ in 0..depth {
                v = d.unary(*rng.choose(&[Op::Tanh, Op::Exp, Op::Abs]), v);
            }
            d.store_affine(v, 2048, vec![1], 1);
            d
        }
    }
}

fn image_for(rng: &mut Rng, words: usize) -> Vec<f32> {
    let mut image = vec![0.0f32; words];
    for w in image.iter_mut().take(1280) {
        *w = rng.normal() * 0.25;
    }
    image
}

/// Everything an unprofiled caller can observe must match bit-for-bit.
fn assert_observably_identical(case: &str, off: &SimResult, on: &SimResult) {
    assert_eq!(off.cycles, on.cycles, "{case}: cycles");
    assert_eq!(off.fires, on.fires, "{case}: fires");
    assert_eq!(off.smem, on.smem, "{case}: smem stats");
    assert_eq!(off.mem.len(), on.mem.len(), "{case}");
    for (i, (a, b)) in off.mem.iter().zip(on.mem.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{case} mem[{i}]: {a} vs {b}");
    }
    assert_eq!(
        off.avg_parallelism.to_bits(),
        on.avg_parallelism.to_bits(),
        "{case}: avg_parallelism"
    );
    assert_eq!(off.measured_ii.to_bits(), on.measured_ii.to_bits(), "{case}: measured_ii");
}

/// Pin 1 (solo): telemetry-on is bit- and cycle-identical to telemetry-off
/// for randomized kernels, with and without a sampled timeline, and the
/// skip counter (part of the engine's observable behaviour) agrees too.
#[test]
fn profiled_solo_runs_are_bit_and_cycle_identical() {
    let m = machine();
    let words = m.smem.as_ref().unwrap().words();
    for case in 0..16usize {
        let mut rng = Rng::new(11_000 + case as u64);
        let d = random_kernel(&mut rng, case);
        d.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
        let image = image_for(&mut rng, words);
        let mapping = compile(d, &m, 300 + case as u64)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));

        let (off, skipped_off) = simulate_counting(&mapping, &m, &image, 2_000_000).unwrap();
        assert!(off.telemetry.is_none(), "case {case}: unprofiled run must carry None");

        for stride in [0u64, 32] {
            let opts = SimOptions { profile: true, sample_stride: stride };
            let (on, skipped_on) =
                simulate_counting_with(&mapping, &m, &image, 2_000_000, &opts).unwrap();
            let label = format!("case {case} stride {stride}");
            assert_observably_identical(&label, &off, &on);
            assert_eq!(skipped_off, skipped_on, "{label}: skipped cycles");
            let t = on.telemetry.as_ref().unwrap_or_else(|| panic!("{label}: no telemetry"));
            assert_eq!(t.sim_cycles, on.cycles, "{label}");
            assert_eq!(t.fires, on.fires, "{label}");
            assert_eq!(t.sample_stride, stride, "{label}");
            assert_eq!(t.timeline.is_empty(), stride == 0, "{label}");
        }
    }
}

/// Pin 1 (batched): a profiled 5-lane arena matches both the unprofiled
/// arena and the profiled solo runs, lane by lane.
#[test]
fn profiled_arena_batches_match_solo_runs() {
    let m = machine();
    let words = m.smem.as_ref().unwrap().words();
    for case in 0..4usize {
        let mut rng = Rng::new(13_000 + case as u64);
        let d = random_kernel(&mut rng, case);
        let mapping = compile(d, &m, 500 + case as u64).unwrap();
        let images: Vec<Vec<f32>> = (0..5).map(|_| image_for(&mut rng, words)).collect();
        let specs: Vec<LaneSpec> = images
            .iter()
            .map(|image| LaneSpec { mapping: &mapping, machine: &m, image })
            .collect();

        let opts = SimOptions { profile: true, sample_stride: 16 };
        let off = simulate_batch(&specs, 2_000_000);
        let on = simulate_batch_with(&specs, 2_000_000, &opts);
        assert_eq!(off.len(), 5);
        assert_eq!(on.len(), 5);
        for (lane, (o, p)) in off.iter().zip(on.iter()).enumerate() {
            let (o, o_skip) = o.as_ref().unwrap_or_else(|e| panic!("case {case}: {e}"));
            let (p, p_skip) = p.as_ref().unwrap_or_else(|e| panic!("case {case}: {e}"));
            let label = format!("case {case} lane {lane}");
            assert_observably_identical(&label, o, p);
            assert_eq!(o_skip, p_skip, "{label}: skipped cycles");
            assert!(o.telemetry.is_none(), "{label}");

            // And the profiled lane is identical to its profiled solo run,
            // telemetry included — lanes share no observable state.
            let (solo, _) =
                simulate_counting_with(&mapping, &m, &images[lane], 2_000_000, &opts).unwrap();
            assert_observably_identical(&format!("{label} vs solo"), &solo, p);
            assert_eq!(solo.telemetry, p.telemetry, "{label}: telemetry");
        }
    }
}

/// Pin 2: exact cycle attribution. Every node-cycle is a fire or exactly
/// one stall, through skip spans and the drain tail; the timeline tiles
/// the run and its per-row fire counts re-sum to the fire counter.
#[test]
fn stall_accounting_is_exact() {
    let m = machine();
    let words = m.smem.as_ref().unwrap().words();
    for case in 0..12usize {
        let mut rng = Rng::new(17_000 + case as u64);
        let d = random_kernel(&mut rng, case);
        let image = image_for(&mut rng, words);
        let mapping: Mapping = compile(d, &m, 700 + case as u64).unwrap();
        let n_nodes = mapping.dfg.nodes.len() as u64;

        let opts = SimOptions { profile: true, sample_stride: 64 };
        let (res, _) = simulate_counting_with(&mapping, &m, &image, 2_000_000, &opts).unwrap();
        let t = res.telemetry.as_ref().unwrap();

        let stalled: u64 = t.stalls.iter().sum();
        assert_eq!(
            stalled,
            n_nodes * res.cycles - res.fires,
            "case {case}: {} nodes x {} cycles - {} fires, histogram {:?}",
            n_nodes,
            res.cycles,
            res.fires,
            t.stalls
        );

        // Per-PE counters re-aggregate to the lane totals; drained cycles
        // are lane-wide (not attributed to any PE).
        let pe_fires: u64 = t.pe.iter().map(|a| a.fires).sum();
        let pe_stalls: u64 = t.pe.iter().map(|a| a.stalls).sum();
        let live: u64 = t.stalls[..StallCause::Drained as usize].iter().sum();
        assert_eq!(pe_fires, res.fires, "case {case}");
        assert_eq!(pe_stalls, live, "case {case}");

        // Timeline: spans tile [0, cycles] gaplessly; windowed fire counts
        // re-sum to the engine's fire counter.
        let mut cursor = 0u64;
        let mut windowed_fires = 0u64;
        for span in &t.timeline {
            assert_eq!(span.start, cursor, "case {case}: timeline gap");
            cursor += span.dur;
            windowed_fires += span.rows_fired.iter().map(|&f| f as u64).sum::<u64>();
        }
        assert_eq!(cursor, res.cycles, "case {case}: timeline must cover the run");
        assert_eq!(windowed_fires, res.fires, "case {case}: windowed fires");

        // The utilization/bottleneck accessors stay finite and in range.
        let u = t.utilization();
        assert!(u.is_finite() && (0.0..=1.0).contains(&u), "case {case}: {u}");
        if let Some((name, pct)) = t.bottleneck() {
            assert!(STALL_NAMES.contains(&name), "case {case}");
            assert!(pct > 0.0 && pct <= 100.0, "case {case}: {pct}");
        }
    }
}

/// Pin 3: fuzzed codec round-trip. Counters are drawn across the full u64
/// range (far above 2^53) and must survive encode→decode bit-exactly.
#[test]
fn telemetry_codec_roundtrip_fuzz() {
    for seed in 0..32u64 {
        let mut rng = Rng::new(23_000 + seed);
        let wide = |rng: &mut Rng| rng.next_u64() | (1u64 << 54); // force > 2^53
        let rows = rng.range(1, 5);
        let banks = rng.range(1, 4);
        let pe: Vec<PeActivity> = (0..rows)
            .map(|r| PeActivity {
                row: r as u32,
                col: rng.range(0, 4) as u32,
                fires: wide(&mut rng),
                stalls: rng.next_u64(),
            })
            .collect();
        let timeline: Vec<TimelineSpan> = (0..rng.range(0, 3))
            .map(|i| TimelineSpan {
                start: i as u64 * 64,
                dur: 64,
                rows_fired: (0..rows).map(|_| rng.next_u64() as u32).collect(),
                bank_conflicts: (0..banks).map(|_| rng.next_u64() as u32).collect(),
            })
            .collect();
        let mut stalls = [0u64; STALL_NAMES.len()];
        for s in stalls.iter_mut() {
            *s = wide(&mut rng);
        }
        let telemetry = TelemetrySummary {
            sim_cycles: wide(&mut rng),
            fires: wide(&mut rng),
            stalls,
            pe,
            bank_conflicts: (0..banks).map(|_| wide(&mut rng)).collect(),
            sample_stride: 64,
            timeline,
        };
        let res = SimResult {
            cycles: wide(&mut rng),
            mem: vec![1.5f32, -0.0, f32::MIN_POSITIVE],
            fires: wide(&mut rng),
            smem: Default::default(),
            avg_parallelism: 3.25,
            measured_ii: 2.5,
            telemetry: Some(telemetry),
        };
        let bytes = encode_sim(&res);
        let back = decode_sim(&bytes).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(back.cycles, res.cycles, "seed {seed}");
        assert_eq!(back.fires, res.fires, "seed {seed}");
        assert_eq!(back.telemetry, res.telemetry, "seed {seed}: telemetry must round-trip");
        // Canonical: re-encoding the decoded value is byte-identical.
        assert_eq!(encode_sim(&back), bytes, "seed {seed}");
    }
}
