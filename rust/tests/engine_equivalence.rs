//! Equivalence suite for the optimized cycle-accurate engine.
//!
//! The simulator hot-loop perf pass (EXPERIMENTS.md §Perf) must be
//! *observationally invisible*: for every kernel the optimized engine has
//! to produce
//!
//! 1. a memory image **bit-identical** to the sequential reference
//!    interpreter ([`windmill::compiler::dfg::interpret`]), and
//! 2. cycle counts, fire counts, smem statistics and derived metrics
//!    **identical** to the frozen pre-refactor engine
//!    ([`windmill::sim::reference`]). The reference shares the
//!    machine-derived window/MSHR sizing and the tag-overflow guard with
//!    the optimized engine (see its module docs); on the standard machine
//!    used here those equal the historical constants, so this pins the
//!    true pre-refactor timing semantics.
//!
//! The batch below sweeps randomized kernels mixing affine loads/stores,
//! indirect (gather/scatter) accesses, accumulators with varying reset
//! periods, 1-D and 2-D nests, and ALU/MUL/SFU op chains.
//!
//! The suite also pins the sweep-level SimResult cache: a warm
//! [`SweepEngine`] re-run must never re-enter `simulate()`.

use windmill::arch::isa::Op;
use windmill::arch::params::ParamGrid;
use windmill::arch::presets;
use windmill::compiler::{compile, dfg::interpret, Dfg};
use windmill::coordinator::{SweepEngine, Workload};
use windmill::plugins;
use windmill::sim::engine::{simulate, simulate_counting};
use windmill::sim::reference::simulate_reference;
use windmill::sim::{MachineDesc, SimResult};
use windmill::util::Rng;

fn machine() -> MachineDesc {
    plugins::elaborate(presets::standard()).unwrap().artifact
}

/// Ops that keep values finite for any finite input (no NaN/Inf blowups:
/// bitwise image comparison would treat NaN != NaN as a mismatch).
const BINOPS: [Op; 5] = [Op::Add, Op::Sub, Op::Mul, Op::Min, Op::Max];
const UNOPS: [Op; 4] = [Op::Abs, Op::Neg, Op::Tanh, Op::Add];

/// Random kernel generator, cycling through four shapes:
///  * case % 4 == 0 — 1-D affine load/op pipeline;
///  * case % 4 == 1 — 2-D nest with an accumulator (reset per row) and a
///    periodic store, GEMM-style;
///  * case % 4 == 2 — indirect **gather**: address = index + table base;
///  * case % 4 == 3 — indirect **scatter**: store address computed on the
///    array.
///
/// All addresses stay inside [0, 4096) for the standard machine's smem.
fn random_kernel(rng: &mut Rng, case: usize) -> Dfg {
    match case % 4 {
        0 => {
            let iters = *rng.choose(&[8u32, 16, 32, 64]);
            let mut d = Dfg::new(&format!("affine-{case}"), vec![iters]);
            let n_loads = rng.range(1, 4);
            let mut vals = Vec::new();
            for i in 0..n_loads {
                vals.push(d.load_affine((i as u32) * 64, vec![1]));
            }
            for _ in 0..rng.range(1, 6) {
                let v = if rng.bool(0.6) && vals.len() >= 2 {
                    let a = *rng.choose(&vals);
                    let b = *rng.choose(&vals);
                    d.compute(*rng.choose(&BINOPS), a, b)
                } else {
                    let a = *rng.choose(&vals);
                    d.unary(*rng.choose(&UNOPS), a)
                };
                vals.push(v);
            }
            let last = *vals.last().unwrap();
            d.store_affine(last, 2048, vec![1], 1);
            d
        }
        1 => {
            let outer = *rng.choose(&[2u32, 4, 8]);
            let inner = *rng.choose(&[4u32, 8]);
            let mut d = Dfg::new(&format!("accum-{case}"), vec![outer, inner]);
            let a = d.load_affine(0, vec![inner as i32, 1]);
            let b = d.load_affine(64, vec![0, 1]);
            let mut v = d.compute(*rng.choose(&[Op::Mul, Op::Add]), a, b);
            if rng.bool(0.5) {
                v = d.unary(*rng.choose(&UNOPS), v);
            }
            let acc_op = *rng.choose(&[Op::Add, Op::Max, Op::Min]);
            let init = if acc_op == Op::Add { 0.0 } else { rng.normal() };
            let acc = d.accum(acc_op, v, init, inner);
            d.store_affine(acc, 2048, vec![1, 0], inner);
            d
        }
        2 => {
            let iters = *rng.choose(&[8u32, 16, 32]);
            let mut d = Dfg::new(&format!("gather-{case}"), vec![iters]);
            let idx = d.index(0);
            let base = d.constant(1024.0);
            let addr = d.compute(Op::Add, idx, base);
            let x = d.load_indirect(addr);
            let y = if rng.bool(0.6) { d.unary(*rng.choose(&UNOPS), x) } else { x };
            d.store_affine(y, 2048, vec![1], 1);
            d
        }
        _ => {
            let iters = *rng.choose(&[8u32, 16]);
            let mut d = Dfg::new(&format!("scatter-{case}"), vec![iters]);
            let x = d.load_affine(0, vec![1]);
            let y = d.unary(*rng.choose(&UNOPS), x);
            let sidx = d.index(0);
            let sbase = d.constant(2048.0);
            let saddr = d.compute(Op::Add, sidx, sbase);
            d.store_indirect(y, saddr, 1);
            d
        }
    }
}

/// Satellite requirement: ≥ 20 randomized kernels, bit-identical memory vs
/// the interpreter AND cycle-identical behaviour vs the pre-refactor
/// engine.
#[test]
fn optimized_engine_is_bit_and_cycle_identical() {
    let m = machine();
    let words = m.smem.as_ref().unwrap().words();
    for case in 0..24usize {
        let mut rng = Rng::new(7_000 + case as u64);
        let d = random_kernel(&mut rng, case);
        d.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));

        let mut image = vec![0.0f32; words];
        for w in image.iter_mut().take(1280) {
            *w = rng.normal();
        }
        let mut golden = image.clone();
        interpret(&d, &mut golden).unwrap_or_else(|e| panic!("case {case}: {e}"));

        let mapping = compile(d, &m, 100 + case as u64)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let fast = simulate(&mapping, &m, &image, 2_000_000)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let reference = simulate_reference(&mapping, &m, &image, 2_000_000)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));

        // (1) Bit-identical to the sequential interpreter.
        assert_eq!(fast.mem.len(), golden.len(), "case {case}");
        for (i, (a, b)) in fast.mem.iter().zip(golden.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "case {case} mem[{i}]: sim {a} vs interpreter {b}"
            );
        }

        // (2) Cycle-identical to the pre-refactor semantics.
        assert_eq!(fast.cycles, reference.cycles, "case {case}: cycle count");
        assert_eq!(fast.fires, reference.fires, "case {case}: fire count");
        assert_eq!(fast.smem, reference.smem, "case {case}: smem stats");
        for (i, (a, b)) in fast.mem.iter().zip(reference.mem.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case} mem[{i}] vs reference");
        }
        assert!(
            (fast.avg_parallelism - reference.avg_parallelism).abs() < 1e-12,
            "case {case}: {} vs {}",
            fast.avg_parallelism,
            reference.avg_parallelism
        );
        assert!(
            (fast.measured_ii - reference.measured_ii).abs() < 1e-12,
            "case {case}: {} vs {}",
            fast.measured_ii,
            reference.measured_ii
        );
    }
}

/// Field-by-field equivalence of two engine results (bitwise on memory).
fn assert_cycle_identical(case: &str, fast: &SimResult, reference: &SimResult) {
    assert_eq!(fast.cycles, reference.cycles, "{case}: cycle count");
    assert_eq!(fast.fires, reference.fires, "{case}: fire count");
    assert_eq!(fast.smem, reference.smem, "{case}: smem stats");
    assert_eq!(fast.mem.len(), reference.mem.len(), "{case}");
    for (i, (a, b)) in fast.mem.iter().zip(reference.mem.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{case} mem[{i}]: {a} vs {b}");
    }
    assert!(
        (fast.avg_parallelism - reference.avg_parallelism).abs() < 1e-12,
        "{case}: {} vs {}",
        fast.avg_parallelism,
        reference.avg_parallelism
    );
    assert!(
        (fast.measured_ii - reference.measured_ii).abs() < 1e-12,
        "{case}: {} vs {}",
        fast.measured_ii,
        reference.measured_ii
    );
}

/// Satellite requirement (PR 4): the event-driven cycle skip is
/// observationally invisible on *stall-heavy* kernels — long-latency SFU
/// chains over shallow iteration spaces, where whole delivery latencies
/// pass with every node stalled — and it actually engages (>0 skipped
/// cycles), which the tick-everything reference engine never does.
#[test]
fn stall_heavy_sfu_chains_are_cycle_identical_and_skip() {
    let m = machine();
    let words = m.smem.as_ref().unwrap().words();
    let mut total_skipped = 0u64;
    for case in 0..12usize {
        let mut rng = Rng::new(9_000 + case as u64);
        // 1-4 iterations × 3-8 chained SFU/ALU ops: the shallow cases are
        // guaranteed to stall on every inter-stage delivery.
        let iters = *rng.choose(&[1u32, 2, 2, 4]);
        let depth = rng.range(3, 9);
        let mut d = Dfg::new(&format!("sfu-stall-{case}"), vec![iters]);
        let mut v = d.load_affine(0, vec![1]);
        for _ in 0..depth {
            v = d.unary(*rng.choose(&[Op::Tanh, Op::Exp, Op::Tanh, Op::Abs]), v);
        }
        d.store_affine(v, 2048, vec![1], 1);

        let mut image = vec![0.0f32; words];
        for w in image.iter_mut().take(64) {
            // Keep exp chains finite-ish; infinities would still compare
            // bitwise, but finite values exercise more of the datapath.
            *w = rng.normal() * 0.25 - 0.5;
        }
        let mut golden = image.clone();
        interpret(&d, &mut golden).unwrap_or_else(|e| panic!("case {case}: {e}"));

        let mapping = compile(d, &m, 300 + case as u64)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let (fast, skipped) = simulate_counting(&mapping, &m, &image, 2_000_000)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let reference = simulate_reference(&mapping, &m, &image, 2_000_000)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_cycle_identical(&format!("case {case}"), &fast, &reference);
        for (i, (a, b)) in fast.mem.iter().zip(golden.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case} mem[{i}] vs interpreter");
        }
        total_skipped += skipped;
    }
    assert!(total_skipped > 0, "stall-heavy suite never engaged the calendar jump");
}

/// Satellite requirement (PR 4): the cycle skip is equally invisible on
/// the non-affine gather path (`spmv` — indirect loads through the LSU),
/// where memory stalls must *inhibit* skipping rather than corrupt it.
#[test]
fn spmv_gather_is_cycle_identical_under_the_skipping_engine() {
    let m = machine();
    for (seed, rows, cols, k) in [(11u64, 16u32, 24u32, 4u32), (12, 8, 40, 8)] {
        let wl = Workload::Spmv { rows, cols, k };
        let (dfgs, layout) = wl.build();
        let image = wl.init_image(&layout, seed, m.smem.as_ref().unwrap().words());
        let mapping = compile(dfgs[0].clone(), &m, seed).unwrap();
        let (fast, skipped) = simulate_counting(&mapping, &m, &image, 2_000_000).unwrap();
        let reference = simulate_reference(&mapping, &m, &image, 2_000_000).unwrap();
        assert_cycle_identical(&format!("spmv seed {seed}"), &fast, &reference);
        assert!(skipped < fast.cycles, "spmv seed {seed}");
    }
}

/// PR 5 (BFS workload): the chained-indirect path — an indirect load
/// whose *address* comes from another indirect load's value — with
/// data-dependent trip counts predicated onto the static nest. Every BFS
/// level phase must be bit-identical to the interpreter and
/// cycle-identical to the reference engine, with the level phases chained
/// through memory exactly as the task runner chains them.
#[test]
fn bfs_chained_indirect_is_bit_and_cycle_identical() {
    let m = machine();
    let words = m.smem.as_ref().unwrap().words();
    for (seed, n, deg, levels) in [(21u64, 24u32, 3u32, 3u32), (22, 40, 5, 2), (23, 16, 2, 4)] {
        let wl = Workload::Bfs { n, deg, levels };
        let (dfgs, layout) = wl.build();
        assert_eq!(dfgs.len(), levels as usize);
        let mut image = wl.init_image(&layout, seed, words);
        let mut golden = image.clone();
        for (lvl, d) in dfgs.iter().enumerate() {
            interpret(d, &mut golden).unwrap_or_else(|e| panic!("seed {seed} l{lvl}: {e}"));
            let mapping = compile(d.clone(), &m, seed).unwrap();
            let (fast, skipped) = simulate_counting(&mapping, &m, &image, 2_000_000)
                .unwrap_or_else(|e| panic!("seed {seed} l{lvl}: {e}"));
            let reference = simulate_reference(&mapping, &m, &image, 2_000_000).unwrap();
            assert_cycle_identical(&format!("bfs seed {seed} level {lvl}"), &fast, &reference);
            assert!(skipped < fast.cycles);
            // Next level starts from this level's engine-produced image.
            image = fast.mem;
        }
        for (i, (a, b)) in image.iter().zip(golden.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "seed {seed} mem[{i}] vs interpreter");
        }
        // The run did real graph work: the source's component got labeled.
        let dist =
            layout.read(&image, windmill::workloads::graph::dist_region(levels));
        assert!(dist.iter().any(|&x| x >= 1.0 && x < windmill::workloads::graph::INF_DIST));
    }
}

/// Regression (satellite): iteration tags pack `(node << 32) | iter`; a
/// nest with ≥ 2^32 iterations must be rejected by both engines instead of
/// silently corrupting iteration ids.
#[test]
fn huge_iteration_spaces_are_rejected_not_truncated() {
    let m = machine();
    let mut d = Dfg::new("huge", vec![1 << 16, 1 << 16]); // 2^32 iterations
    let x = d.load_affine(0, vec![0, 0]);
    d.store_affine(x, 1, vec![0, 0], 1);
    let mapping = compile(d, &m, 1).unwrap();
    let image = vec![0.0f32; 16];
    let err = simulate(&mapping, &m, &image, 100).map(|_| ()).unwrap_err();
    assert!(err.to_string().contains("iteration tag"), "{err}");
    let err_ref =
        simulate_reference(&mapping, &m, &image, 100).map(|_| ()).unwrap_err();
    assert!(err_ref.to_string().contains("iteration tag"), "{err_ref}");
}

/// PR 6 (batched arena): randomized multi-point batches — one shared DFG
/// mapped onto *different* machines (context-depth variants), different
/// mapper seeds and different memory images per lane — where every lane of
/// one [`simulate_batch`] launch must be bit-identical to the sequential
/// interpreter, cycle-identical to the pre-refactor reference engine, and
/// exactly equal (result *and* skipped-cycle count) to running that lane
/// alone through `simulate_counting`. Lockstep interleaving must be
/// unobservable.
#[test]
fn batched_arena_lanes_are_bit_and_cycle_identical() {
    let machines: Vec<MachineDesc> = [32usize, 64, 128]
        .iter()
        .map(|&depth| {
            let mut p = presets::standard();
            p.context_depth = depth;
            plugins::elaborate(p).unwrap().artifact
        })
        .collect();
    let words = machines[0].smem.as_ref().unwrap().words();
    for case in 0..8usize {
        let mut rng = Rng::new(17_000 + case as u64);
        let d = random_kernel(&mut rng, case);
        d.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
        // One mapping per machine variant, each with its own mapper seed:
        // lanes of a batch legitimately differ in placement, not just image.
        let mappings: Vec<_> = machines
            .iter()
            .enumerate()
            .map(|(k, m)| {
                compile(d.clone(), m, 500 + (case * 7 + k) as u64)
                    .unwrap_or_else(|e| panic!("case {case} machine {k}: {e}"))
            })
            .collect();
        let images: Vec<Vec<f32>> = (0..5)
            .map(|_| {
                let mut img = vec![0.0f32; words];
                for w in img.iter_mut().take(1280) {
                    *w = rng.normal();
                }
                img
            })
            .collect();
        let lanes: Vec<windmill::sim::LaneSpec> = (0..5)
            .map(|l| windmill::sim::LaneSpec {
                mapping: &mappings[l % 3],
                machine: &machines[l % 3],
                image: &images[l],
            })
            .collect();
        let outs = windmill::sim::simulate_batch(&lanes, 2_000_000);
        assert_eq!(outs.len(), 5, "case {case}");
        for (l, out) in outs.into_iter().enumerate() {
            let tag = format!("case {case} lane {l}");
            let (fast, skipped) = out.unwrap_or_else(|e| panic!("{tag}: {e}"));

            // (1) Bit-identical to the interpreter on this lane's image.
            let mut golden = images[l].clone();
            interpret(&d, &mut golden).unwrap_or_else(|e| panic!("{tag}: {e}"));
            for (i, (a, b)) in fast.mem.iter().zip(golden.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag} mem[{i}] vs interpreter");
            }

            // (2) Cycle-identical to the pre-refactor reference engine.
            let reference =
                simulate_reference(&mappings[l % 3], &machines[l % 3], &images[l], 2_000_000)
                    .unwrap_or_else(|e| panic!("{tag}: {e}"));
            assert_cycle_identical(&tag, &fast, &reference);

            // (3) Exactly the solo engine run, skip counter included.
            let (solo, solo_skipped) =
                simulate_counting(&mappings[l % 3], &machines[l % 3], &images[l], 2_000_000)
                    .unwrap_or_else(|e| panic!("{tag}: {e}"));
            assert_cycle_identical(&format!("{tag} vs solo"), &fast, &solo);
            assert_eq!(skipped, solo_skipped, "{tag}: skipped-cycle counter");
        }
    }
}

/// Satellite requirement: on a warm [`SweepEngine`] run, `simulate()` is
/// never re-entered — every phase answers from the SimResult cache (the
/// cache records a `simulate` miss exactly when it invokes the engine, so
/// zero warm misses ⇔ zero warm `simulate()` entries).
#[test]
fn warm_sweep_never_reenters_the_simulator() {
    let engine = SweepEngine::new(2);
    let grid = ParamGrid::new(presets::standard()).pea_edges(&[4, 8]);
    let wl = Workload::Saxpy { n: 64 };

    let cold = engine.sweep(&grid, &wl);
    assert!(cold.failures.is_empty(), "{:?}", cold.failures);
    let (cold_hits, cold_misses) = cold.cache.pass_counts("simulate");
    assert_eq!(cold_hits, 0, "cold sweep cannot hit");
    assert!(cold_misses >= 2, "one simulation per grid point: {:?}", cold.cache);

    let warm = engine.sweep(&grid, &wl);
    let (warm_hits, warm_misses) = warm.cache.pass_counts("simulate");
    assert_eq!(warm_misses, 0, "warm sweep must never re-enter simulate()");
    assert!(warm_hits >= 2);
    assert_eq!(warm.sim_hit_rate(), 1.0, "{:?}", warm.cache);
    assert!(warm.summary().contains("sim cache"));

    // And the warm numbers are the cold numbers, bit for bit.
    let key = |r: &windmill::coordinator::SweepReport| {
        let mut v: Vec<(String, u64, f64)> = r
            .points
            .iter()
            .map(|p| (p.label.clone(), p.cycles, p.wm_time_ns))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    };
    assert_eq!(key(&cold), key(&warm));
}
