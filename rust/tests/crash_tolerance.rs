//! Crash-tolerance system tests: a leased sweep worker is SIGKILLed
//! mid-shard, a second worker steals the expired lease and finishes the
//! session, and the merged report is still bit-identical to the unsharded
//! sweep — with zero duplicate evaluations recorded in the manifest.
//!
//! These tests drive the real `windmill` binary (the same processes a
//! cluster would run), so the kill is a genuine `SIGKILL`: no destructors,
//! no flushes, the lease simply stops heartbeating.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use windmill::arch::params::ParamGrid;
use windmill::arch::{presets, Topology};
use windmill::coordinator::{SweepEngine, Workload};
use windmill::store::{LeaseBoard, SweepSession};

/// Unique per-test scratch directory, removed on drop.
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let dir = std::env::temp_dir()
            .join(format!("windmill-crashtest-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }

    fn path(&self) -> &std::path::Path {
        &self.0
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// The CLI `sweep` grid, mirrored so the in-process baseline evaluates the
/// exact points the binary does.
fn cli_grid() -> ParamGrid {
    ParamGrid::new(presets::standard()).pea_edges(&[4, 8, 12, 16]).topologies(&Topology::ALL)
}

/// Satellite acceptance: worker 1 is killed (SIGKILL) while holding a
/// lease; worker 2, pointed at the same store, completes the free ranges,
/// waits out the dead worker's lease on the epoch clock, steals it, and
/// prints a merged frontier byte-identical to the unsharded sweep. The
/// manifest records each range exactly once — no duplicate evaluations.
#[test]
fn killed_lease_worker_is_stolen_from_and_the_merge_stays_bit_identical() {
    let tmp = TempDir::new("kill-resume");
    let manifest = SweepSession::manifest_path(tmp.path());

    // Worker 1: spawn the real binary and SIGKILL it as soon as its first
    // lease acquisition lands in the manifest — i.e. mid-shard, before any
    // checkpoint exists.
    let mut victim = std::process::Command::new(env!("CARGO_BIN_EXE_windmill"))
        .args(["sweep", "dot", "--workers", "2", "--lease", "--ranges", "4"])
        .args(["--worker-id", "1", "--store"])
        .arg(tmp.path())
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn lease worker 1");
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let acquired = std::fs::read_to_string(&manifest)
            .map(|t| t.contains("\"state\":\"acquire\""))
            .unwrap_or(false);
        if acquired {
            break;
        }
        assert!(Instant::now() < deadline, "worker 1 never acquired a lease");
        assert!(
            victim.try_wait().expect("poll worker 1").is_none(),
            "worker 1 exited before it could be killed mid-shard"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    victim.kill().expect("SIGKILL worker 1");
    let _ = victim.wait();

    // The dead worker left a held, never-completed lease behind.
    let suite_hash = windmill::coordinator::WorkloadSuite::parse("dot").unwrap().fingerprint();
    let grid_hash = SweepSession::grid_hash(&cli_grid());
    let board = LeaseBoard::read(&manifest);
    assert!(!board.entries.is_empty());
    assert!(!board.session_complete(suite_hash, grid_hash, 42, 4));

    // Worker 2: same store, different identity. It must finish the whole
    // session, stealing the dead lease once it ages out.
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_windmill"))
        .args(["sweep", "dot", "--workers", "2", "--lease", "--ranges", "4"])
        .args(["--worker-id", "2", "--store"])
        .arg(tmp.path())
        .output()
        .expect("spawn lease worker 2");
    let stderr = String::from_utf8_lossy(&out.stderr).to_string();
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(out.status.success(), "worker 2 failed:\n{stderr}");
    assert!(
        stderr.contains("1 stolen"),
        "worker 2 must report stealing the dead worker's lease:\n{stderr}"
    );

    // Recovery is visible in the merged report, not silently absorbed.
    assert!(stdout.contains("recovery"), "summary must carry the recovery segment:\n{stdout}");

    // Zero duplicate evaluations: every range has exactly one shard line.
    let (entries, skipped) = SweepSession::read_manifest(tmp.path());
    assert_eq!(skipped, 0, "lease lines must not read as garbage");
    let mut shards: Vec<u32> = entries.iter().map(|e| e.shard).collect();
    shards.sort_unstable();
    assert_eq!(shards, vec![0, 1, 2, 3], "duplicate or missing shard lines: {entries:?}");
    assert!(
        LeaseBoard::read(&manifest).session_complete(suite_hash, grid_hash, 42, 4),
        "every lease completed"
    );

    // The merged frontier is byte-identical to the unsharded sweep (same
    // lines the CLI prints for a plain `windmill sweep dot`).
    let full = SweepEngine::new(2).sweep_seeded(&cli_grid(), &Workload::Dot { n: 256 }, 42);
    for p in full.frontier_points() {
        let line = format!(
            "  * {:<20} {:>7.3} mm2  {:>6.2} mW  {:>9} cycles",
            p.label, p.area_mm2, p.power_mw, p.cycles
        );
        assert!(stdout.contains(&line), "missing frontier line `{line}` in:\n{stdout}");
    }

    // And the checkpoints themselves merge to the same points, bit for bit.
    let (partials, bad) = SweepSession::load_partials(tmp.path()).unwrap();
    assert_eq!(bad, 0);
    let merged = SweepSession::merge(partials).unwrap();
    assert_eq!(merged.points.len(), full.points.len());
    for (a, b) in merged.points.iter().zip(full.points.iter()) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits());
        assert_eq!(a.power_mw.to_bits(), b.power_mw.to_bits());
        assert_eq!(a.wm_time_ns.to_bits(), b.wm_time_ns.to_bits());
    }
    assert_eq!(merged.frontier, full.frontier);
    assert!(merged.recovery.steals >= 1, "{:?}", merged.recovery);
}

/// The lease flag grammar is validated up front: every misuse is a clean
/// CLI error, never a half-started session.
#[test]
fn lease_flag_misuse_is_rejected() {
    let tmp = TempDir::new("flags");
    let run = |args: &[&str]| {
        let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_windmill"));
        cmd.args(args);
        cmd.output().expect("spawn windmill")
    };
    let cases: &[&[&str]] = &[
        &["sweep", "dot", "--lease"],          // no --store
        &["sweep", "dot", "--chaos", "7"],     // chaos without lease
        &["sweep", "dot", "--ranges", "4"],    // ranges without lease
        &["sweep", "dot", "--ttl", "8"],       // ttl without lease
        &["sweep", "dot", "--worker-id", "1"], // id without lease
    ];
    for case in cases {
        let out = run(case);
        assert!(!out.status.success(), "{case:?} must fail");
    }
    // --lease conflicts with --shard and --drive even with a store.
    let store = tmp.path().to_string_lossy().to_string();
    for extra in [["--shard", "0/2"], ["--drive", "halving"]] {
        let out =
            run(&["sweep", "dot", "--store", store.as_str(), "--lease", extra[0], extra[1]]);
        assert!(!out.status.success(), "--lease with {} must fail", extra[0]);
    }
}
