"""Layer-2 JAX compute graphs for the WindMill baselines.

These are the workloads the paper's evaluation runs on the CGRA, written as
jax functions whose dense hot-spots call the Layer-1 Pallas kernel
(`kernels.matmul.matmul_bias_act`). `aot.py` lowers each entry point once to
HLO text; the Rust coordinator executes them through PJRT as the GPU-analog
baseline and as the golden numeric reference for the cycle-accurate CGRA
simulator. Python is never on the request path.

Shapes are fixed at AOT time (see `SHAPES`): the RL policy is a 2-layer tanh
MLP (obs 4 -> hidden 32 -> 2 actions) trained with REINFORCE over batches of
64 transitions — the small-batch regime where the paper reports 2.3x vs GPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import matmul as mk

# --------------------------------------------------------------------------
# Fixed AOT shapes (single source of truth, mirrored into manifest.json).
# --------------------------------------------------------------------------
OBS_DIM = 4
HIDDEN = 32
ACT_DIM = 2
BATCH = 64
LR = 0.05

GEMM_M = 64
GEMM_K = 64
GEMM_N = 64

FIR_N = 256
FIR_TAPS = 16

CONV_H = 32
CONV_W = 32

# Block shapes for the Pallas kernel at these problem sizes. A 128x128x128
# MXU tile would be >99% padding for the RL shapes; 32/32/32 keeps the tile
# resident in a few KiB of VMEM with no wasted K slabs (see §Perf).
BLOCK = dict(bm=32, bn=32, bk=32)


def _mm(x, w, b, act=mk.ACT_NONE):
    return mk.matmul_bias_act(x, w, b, act=act, **BLOCK)


# --------------------------------------------------------------------------
# Linear algebra domain: plain GEMM.
# --------------------------------------------------------------------------
def gemm(x, w, b):
    """out = x @ w + b, (64,64)x(64,64)+(64,) — the CGRA GEMM golden ref."""
    return (_mm(x, w, b),)


# --------------------------------------------------------------------------
# Reinforcement-learning domain: REINFORCE policy gradient.
# --------------------------------------------------------------------------
def policy_forward(w1, b1, w2, b2, obs):
    """Batched policy logits. Hot spots are the two Pallas matmuls."""
    h = _mm(obs, w1, b1, act=mk.ACT_TANH)
    logits = _mm(h, w2, b2, act=mk.ACT_NONE)
    return (logits,)


def _policy_loss(params, obs, act_onehot, returns):
    w1, b1, w2, b2 = params
    (logits,) = policy_forward(w1, b1, w2, b2, obs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    chosen = jnp.sum(logp * act_onehot, axis=-1)
    return -jnp.mean(returns * chosen)


def policy_step(w1, b1, w2, b2, obs, act_onehot, returns):
    """One REINFORCE SGD step: returns (w1', b1', w2', b2', loss).

    The backward pass is jax.grad through the Pallas forward, so the AOT'd
    HLO contains both fwd and bwd of the Layer-1 kernel.
    """
    loss, grads = jax.value_and_grad(_policy_loss)(
        (w1, b1, w2, b2), obs, act_onehot, returns
    )
    g1, gb1, g2, gb2 = grads
    return (
        w1 - LR * g1,
        b1 - LR * gb1,
        w2 - LR * g2,
        b2 - LR * gb2,
        loss,
    )


# --------------------------------------------------------------------------
# Signal-processing domain: FIR filter and 3x3 conv, both im2col'd onto the
# Pallas GEMM (the same trick the CGRA mapper uses to feed its PEA).
# --------------------------------------------------------------------------
def fir(signal, taps):
    """Valid-mode FIR via im2col: windows (N-T+1, T) @ taps (T, 1)."""
    n = FIR_N - FIR_TAPS + 1
    idx = jnp.arange(n)[:, None] + jnp.arange(FIR_TAPS)[None, :]
    windows = signal[idx]
    zero = jnp.zeros((1,), signal.dtype)
    out = _mm(windows, taps.reshape(FIR_TAPS, 1), zero)
    return (out.reshape(n),)


def conv2d_3x3(image, kernel):
    """Valid 3x3 single-channel conv via im2col onto the Pallas GEMM."""
    oh, ow = CONV_H - 2, CONV_W - 2
    ii = jnp.arange(oh)[:, None, None, None] + jnp.arange(3)[None, None, :, None]
    jj = jnp.arange(ow)[None, :, None, None] + jnp.arange(3)[None, None, None, :]
    patches = image[ii, jj].reshape(oh * ow, 9)
    zero = jnp.zeros((1,), image.dtype)
    out = _mm(patches, kernel.reshape(9, 1), zero)
    return (out.reshape(oh, ow),)


# --------------------------------------------------------------------------
# AOT entry-point registry: name -> (fn, input ShapeDtypeStructs).
# --------------------------------------------------------------------------
def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


ENTRY_POINTS = {
    "gemm": (gemm, [_f32(GEMM_M, GEMM_K), _f32(GEMM_K, GEMM_N), _f32(GEMM_N)]),
    "policy_forward": (
        policy_forward,
        [
            _f32(OBS_DIM, HIDDEN),
            _f32(HIDDEN),
            _f32(HIDDEN, ACT_DIM),
            _f32(ACT_DIM),
            _f32(BATCH, OBS_DIM),
        ],
    ),
    "policy_step": (
        policy_step,
        [
            _f32(OBS_DIM, HIDDEN),
            _f32(HIDDEN),
            _f32(HIDDEN, ACT_DIM),
            _f32(ACT_DIM),
            _f32(BATCH, OBS_DIM),
            _f32(BATCH, ACT_DIM),
            _f32(BATCH),
        ],
    ),
    "fir": (fir, [_f32(FIR_N), _f32(FIR_TAPS)]),
    "conv2d_3x3": (conv2d_3x3, [_f32(CONV_H, CONV_W), _f32(3, 3)]),
}
