"""AOT compile path: lower every Layer-2 entry point to HLO *text*.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` —
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla_extension 0.5.1 bundled with the Rust `xla` crate rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. Lowering goes stablehlo -> XlaComputation (return_tuple=True, so
the Rust side always unwraps a tuple) -> as_hlo_text. See
/opt/xla-example/gen_hlo.py for the reference wiring.

Alongside the ``<name>.hlo.txt`` files a ``manifest.json`` records every
entry point's input/output shapes so the Rust runtime can marshal literals
without re-deriving shapes.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str):
    fn, in_specs = model.ENTRY_POINTS[name]
    lowered = jax.jit(fn).lower(*in_specs)
    out_specs = jax.eval_shape(fn, *in_specs)
    return lowered, in_specs, out_specs


def spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated subset of entry points"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = sorted(model.ENTRY_POINTS)
    if args.only:
        names = [n for n in names if n in set(args.only.split(","))]

    manifest = {
        "format": "hlo-text/return-tuple",
        "shapes": {
            "obs_dim": model.OBS_DIM,
            "hidden": model.HIDDEN,
            "act_dim": model.ACT_DIM,
            "batch": model.BATCH,
            "lr": model.LR,
            "gemm": [model.GEMM_M, model.GEMM_K, model.GEMM_N],
            "fir": [model.FIR_N, model.FIR_TAPS],
            "conv": [model.CONV_H, model.CONV_W],
        },
        "entries": {},
    }
    for name in names:
        lowered, in_specs, out_specs = lower_entry(name)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [spec_json(s) for s in in_specs],
            "outputs": [spec_json(s) for s in out_specs],
        }
        print(f"  aot: {name}: {len(text)} chars -> {path}")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  aot: manifest -> {mpath}")


if __name__ == "__main__":
    main()
