"""Layer-1 Pallas kernels: tiled matmul with fused bias + activation.

This is the compute hot-spot of every WindMill baseline workload (the RL
policy MLP, GEMM, and the im2col'd FIR/conv all bottom out here). The kernel
is written the way it would be tiled for a real TPU:

  * the grid walks (M/bm, N/bn, K/bk); each (i, j) output tile accumulates
    over the K slabs streamed HBM->VMEM by the BlockSpec index maps;
  * accumulation happens in a float32 VMEM scratch accumulator regardless of
    input dtype (MXU-style mixed precision);
  * bias add + activation are fused into the epilogue so the activation
    never round-trips to HBM.

Autodiff: `pallas_call` has no JVP rule for scratch-carrying grids, so
`matmul_bias_act` carries a `jax.custom_vjp` whose backward pass is built
from the *same* tiled kernel (dx = dpre @ w^T, dw = x^T @ dpre) — the AOT'd
training step therefore runs Pallas in both directions.

On this image the kernel always runs with ``interpret=True`` — the CPU PJRT
plugin cannot execute Mosaic custom-calls — so the BlockSpec structure is
validated functionally and its VMEM/MXU characteristics are estimated
analytically (see DESIGN.md §Perf and EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Activation codes shared with ref.py / model.py.
ACT_NONE = 0
ACT_RELU = 1
ACT_TANH = 2

# Default block shape: MXU-friendly 128x128 output tile, 128-deep K slabs.
# Callers with small problems clamp blocks to the (padded) problem size.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _apply_act(x, act: int):
    if act == ACT_RELU:
        return jnp.maximum(x, 0.0)
    if act == ACT_TANH:
        return jnp.tanh(x)
    return x


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk: int, act: int):
    """One (bm, bn) output tile; grid dim 2 walks the K slabs."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU-style mixed precision: accumulate in f32 whatever the input dtype.
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        out = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_act(out, act).astype(o_ref.dtype)


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


def _pallas_matmul(x, w, b, act, bm, bn, bk, interpret):
    """Raw (non-differentiable) tiled pallas matmul: act(x @ w + b)."""
    m, k = x.shape
    _, n = w.shape

    # Clamp blocks to the problem so tiny shapes stay single-tile.
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    bk = min(bk, max(k, 1))

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    bp = _pad_to(b, bn, 0).reshape(1, -1)

    mp, kp = xp.shape
    np_ = wp.shape[1]
    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _matmul_vjp(x, w, b, act, bm, bn, bk, interpret):
    return _pallas_matmul(x, w, b, act, bm, bn, bk, interpret)


def _matmul_fwd(x, w, b, act, bm, bn, bk, interpret):
    out = _pallas_matmul(x, w, b, act, bm, bn, bk, interpret)
    return out, (x, w, out)


def _matmul_bwd(act, bm, bn, bk, interpret, res, dy):
    x, w, out = res
    # Activation gradient from the *post*-activation value (exact for the
    # three supported activations).
    if act == ACT_RELU:
        dpre = dy * (out > 0).astype(dy.dtype)
    elif act == ACT_TANH:
        dpre = dy * (1.0 - out * out)
    else:
        dpre = dy
    zero_n = jnp.zeros((w.shape[0],), dy.dtype)
    zero_k = jnp.zeros((w.shape[1],), dy.dtype)
    # Backward matmuls reuse the same tiled Pallas kernel.
    dx = _pallas_matmul(dpre, w.T, zero_n, ACT_NONE, bm, bn, bk, interpret)
    dw = _pallas_matmul(x.T, dpre, zero_k, ACT_NONE, bm, bn, bk, interpret)
    db = jnp.sum(dpre, axis=0)
    return dx, dw, db


_matmul_vjp.defvjp(_matmul_fwd, _matmul_bwd)


def matmul_bias_act(
    x,
    w,
    b,
    *,
    act: int = ACT_NONE,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
):
    """``act(x @ w + b)`` with a tiled, differentiable Pallas kernel.

    x: (M, K), w: (K, N), b: (N,). Shapes need not be multiples of the block
    sizes; inputs are zero-padded and the result sliced back (zero padding is
    exact for matmul + bias on the valid region).
    """
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(f"bad ranks: x{x.shape} w{w.shape} b{b.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape[0] != n:
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    if act not in (ACT_NONE, ACT_RELU, ACT_TANH):
        raise ValueError(f"unknown activation code {act}")
    return _matmul_vjp(x, w, b, act, bm, bn, bk, interpret)


# --------------------------------------------------------------------------
# Analytic TPU performance estimators (§Perf): interpret=True gives
# CPU-numpy timings only, so block-shape quality is scored structurally.
# --------------------------------------------------------------------------
def vmem_bytes(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """VMEM footprint of one program instance: double-buffered input tiles +
    f32 accumulator + bias slab + output tile."""
    x_tile = bm * bk * itemsize * 2  # double-buffered HBM->VMEM stream
    w_tile = bk * bn * itemsize * 2
    b_tile = bn * itemsize
    acc = bm * bn * 4
    out = bm * bn * itemsize
    return x_tile + w_tile + b_tile + acc + out


def mxu_utilization(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU-issued MACs doing useful (non-padding) work, times the
    systolic-array occupancy of the tile shape (8x128 lanes, 128x128 MXU)."""
    mp = math.ceil(m / bm) * bm
    np_ = math.ceil(n / bn) * bn
    kp = math.ceil(k / bk) * bk
    useful = (m * n * k) / float(mp * np_ * kp)
    occupancy = min(bm, 128) * min(bn, 128) / (128.0 * 128.0)
    return useful * min(1.0, occupancy)
