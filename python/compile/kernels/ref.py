"""Pure-jnp correctness oracles for the Layer-1 kernels and Layer-2 graphs.

Everything here is straight-line jax.numpy with no Pallas, no tiling and no
padding tricks — the simplest possible statement of the math. pytest checks
the Pallas kernels and the AOT'd model graphs against these oracles, and the
Rust integration tests check the CGRA simulator's numerics against the AOT
artifacts (which are themselves checked against this file). ref.py is the
root of that trust chain, so keep it boring.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

ACT_NONE = 0
ACT_RELU = 1
ACT_TANH = 2


def apply_act(x, act: int):
    if act == ACT_RELU:
        return jnp.maximum(x, 0.0)
    if act == ACT_TANH:
        return jnp.tanh(x)
    return x


def matmul_bias_act(x, w, b, act: int = ACT_NONE):
    """act(x @ w + b) — the oracle for kernels.matmul.matmul_bias_act."""
    return apply_act(
        jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32),
        act,
    ).astype(x.dtype)


def policy_forward(w1, b1, w2, b2, obs):
    """2-layer tanh MLP policy: obs -> logits."""
    h = jnp.tanh(obs @ w1 + b1)
    return h @ w2 + b2


def policy_logprobs(w1, b1, w2, b2, obs):
    logits = policy_forward(w1, b1, w2, b2, obs)
    return jax.nn.log_softmax(logits, axis=-1)


def policy_loss(w1, b1, w2, b2, obs, act_onehot, returns):
    """REINFORCE surrogate: -E[G_t * log pi(a_t | s_t)]."""
    logp = policy_logprobs(w1, b1, w2, b2, obs)
    chosen = jnp.sum(logp * act_onehot, axis=-1)
    return -jnp.mean(returns * chosen)


def policy_step(w1, b1, w2, b2, obs, act_onehot, returns, lr: float):
    """One REINFORCE SGD step; returns (new params..., loss)."""
    loss, grads = jax.value_and_grad(policy_loss, argnums=(0, 1, 2, 3))(
        w1, b1, w2, b2, obs, act_onehot, returns
    )
    g1, gb1, g2, gb2 = grads
    return (w1 - lr * g1, b1 - lr * gb1, w2 - lr * g2, b2 - lr * gb2, loss)


def fir(signal, taps):
    """Direct-form FIR: out[i] = sum_j signal[i + j] * taps[j] (valid mode)."""
    n = signal.shape[0] - taps.shape[0] + 1
    idx = jnp.arange(n)[:, None] + jnp.arange(taps.shape[0])[None, :]
    return signal[idx] @ taps


def conv2d_3x3(image, kernel):
    """Valid-mode single-channel 3x3 convolution (correlation convention)."""
    h, w = image.shape
    out = jnp.zeros((h - 2, w - 2), image.dtype)
    for di in range(3):
        for dj in range(3):
            out = out + kernel[di, dj] * image[di : di + h - 2, dj : dj + w - 2]
    return out
