"""AOT artifact integrity: the HLO text files + manifest the Rust runtime
consumes. These tests re-lower from source and compare against what is on
disk structurally (entry computation present, parameter count and shapes
match the manifest), and they execute the lowered computation through the
local CPU client to pin the numbers the Rust integration tests rely on."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestManifest:
    def test_all_entry_points_present(self):
        man = _manifest()
        assert set(man["entries"]) == set(model.ENTRY_POINTS)

    def test_files_exist_and_nonempty(self):
        man = _manifest()
        for name, ent in man["entries"].items():
            path = os.path.join(ART, ent["file"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) > 100, name

    def test_format_marker(self):
        assert _manifest()["format"] == "hlo-text/return-tuple"

    def test_input_shapes_match_registry(self):
        man = _manifest()
        for name, (_, specs) in model.ENTRY_POINTS.items():
            recorded = man["entries"][name]["inputs"]
            assert len(recorded) == len(specs)
            for r, s in zip(recorded, specs):
                assert tuple(r["shape"]) == tuple(s.shape)
                assert r["dtype"] == str(s.dtype)

    def test_shape_constants_recorded(self):
        sh = _manifest()["shapes"]
        assert sh["batch"] == model.BATCH
        assert sh["hidden"] == model.HIDDEN
        assert sh["lr"] == model.LR


class TestHloText:
    def test_entry_computation_present(self):
        man = _manifest()
        for ent in man["entries"].values():
            with open(os.path.join(ART, ent["file"])) as f:
                text = f.read()
            assert "HloModule" in text
            assert "ENTRY" in text

    def test_relowering_is_deterministic(self):
        """Same source -> same HLO text (stable artifact builds)."""
        a = aot.to_hlo_text(aot.lower_entry("gemm")[0])
        b = aot.to_hlo_text(aot.lower_entry("gemm")[0])
        assert a == b

    def test_policy_step_contains_fused_training_graph(self):
        man = _manifest()
        with open(os.path.join(ART, man["entries"]["policy_step"]["file"])) as f:
            text = f.read()
        # fwd+bwd matmuls: at least 6 dots (2 fwd, 4 bwd) post-fusion.
        assert text.count("dot(") >= 4


class TestExecutedNumbers:
    """Execute the lowered HLO on the in-process CPU client and compare to
    direct evaluation — the same contract the Rust PJRT runtime relies on."""

    def _run_lowered(self, name, *args):
        lowered, _, _ = aot.lower_entry(name)
        compiled = lowered.compile()
        return compiled(*args)

    def test_gemm_roundtrip(self):
        rng = np.random.default_rng(0)
        x = jnp.array(rng.standard_normal((64, 64)).astype(np.float32))
        w = jnp.array(rng.standard_normal((64, 64)).astype(np.float32))
        b = jnp.array(rng.standard_normal(64).astype(np.float32))
        (got,) = self._run_lowered("gemm", x, w, b)
        (want,) = model.gemm(x, w, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_policy_step_roundtrip(self):
        rng = np.random.default_rng(1)
        w1 = jnp.array(rng.standard_normal((model.OBS_DIM, model.HIDDEN)).astype(np.float32) * 0.3)
        b1 = jnp.zeros(model.HIDDEN, jnp.float32)
        w2 = jnp.array(rng.standard_normal((model.HIDDEN, model.ACT_DIM)).astype(np.float32) * 0.3)
        b2 = jnp.zeros(model.ACT_DIM, jnp.float32)
        obs = jnp.array(rng.standard_normal((model.BATCH, model.OBS_DIM)).astype(np.float32))
        onehot = jnp.array(np.eye(model.ACT_DIM, dtype=np.float32)[rng.integers(0, model.ACT_DIM, model.BATCH)])
        rets = jnp.array(rng.standard_normal(model.BATCH).astype(np.float32))
        got = self._run_lowered("policy_step", w1, b1, w2, b2, obs, onehot, rets)
        want = model.policy_step(w1, b1, w2, b2, obs, onehot, rets)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-5)
