"""Layer-1 correctness: the Pallas matmul kernel vs the pure-jnp oracle.

This is the CORE numeric signal of the build path: if these pass, the HLO
artifacts the Rust runtime executes contain a kernel that matches ref.py.
hypothesis sweeps shapes, block shapes, dtypes and activations, including
the ragged cases where the kernel's padding logic has to be exact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mk
from compile.kernels import ref

ACTS = [mk.ACT_NONE, mk.ACT_RELU, mk.ACT_TANH]


def _rand(rng, *shape, dtype=np.float32):
    return rng.standard_normal(shape).astype(dtype)


def _assert_close(got, want, dtype=np.float32):
    tol = 2e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol
    )


# ---------------------------------------------------------------------------
# Deterministic unit cases.
# ---------------------------------------------------------------------------
class TestMatmulBasics:
    def test_identity(self):
        x = jnp.eye(8, dtype=jnp.float32)
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        b = jnp.zeros(8, jnp.float32)
        _assert_close(mk.matmul_bias_act(x, w, b), w)

    def test_bias_only(self):
        x = jnp.zeros((4, 4), jnp.float32)
        w = jnp.zeros((4, 3), jnp.float32)
        b = jnp.array([1.0, -2.0, 3.0], jnp.float32)
        out = mk.matmul_bias_act(x, w, b)
        _assert_close(out, np.tile([1.0, -2.0, 3.0], (4, 1)))

    def test_relu_clamps_negative(self):
        x = jnp.ones((2, 2), jnp.float32)
        w = -jnp.ones((2, 2), jnp.float32)
        b = jnp.zeros(2, jnp.float32)
        out = mk.matmul_bias_act(x, w, b, act=mk.ACT_RELU)
        assert np.all(np.asarray(out) == 0.0)

    def test_tanh_saturates(self):
        x = jnp.full((1, 1), 100.0, jnp.float32)
        w = jnp.ones((1, 1), jnp.float32)
        b = jnp.zeros(1, jnp.float32)
        out = mk.matmul_bias_act(x, w, b, act=mk.ACT_TANH)
        _assert_close(out, [[1.0]])

    def test_single_element(self):
        x = jnp.array([[3.0]], jnp.float32)
        w = jnp.array([[2.0]], jnp.float32)
        b = jnp.array([1.0], jnp.float32)
        _assert_close(mk.matmul_bias_act(x, w, b), [[7.0]])

    def test_rank_validation(self):
        good = jnp.zeros((2, 2), jnp.float32)
        with pytest.raises(ValueError, match="bad ranks"):
            mk.matmul_bias_act(jnp.zeros(2), good, jnp.zeros(2))

    def test_shape_mismatch(self):
        with pytest.raises(ValueError, match="shape mismatch"):
            mk.matmul_bias_act(
                jnp.zeros((2, 3), jnp.float32),
                jnp.zeros((4, 2), jnp.float32),
                jnp.zeros(2, jnp.float32),
            )

    def test_bad_act_code(self):
        good = jnp.zeros((2, 2), jnp.float32)
        with pytest.raises(ValueError, match="activation"):
            mk.matmul_bias_act(good, good, jnp.zeros(2, jnp.float32), act=7)


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes, blocks, activations, dtypes.
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_ragged_shapes(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, m, k), _rand(rng, k, n), _rand(rng, n)
    got = mk.matmul_bias_act(
        jnp.array(x), jnp.array(w), jnp.array(b), act=act, bm=32, bn=32, bk=32
    )
    want = ref.matmul_bias_act(jnp.array(x), jnp.array(w), jnp.array(b), act)
    _assert_close(got, want)


@settings(max_examples=15, deadline=None)
@given(
    bm=st.sampled_from([1, 3, 8, 16, 64]),
    bn=st.sampled_from([1, 5, 8, 32]),
    bk=st.sampled_from([1, 2, 7, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_shape_invariance(bm, bn, bk, seed):
    """The numeric result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, 33, 29), _rand(rng, 29, 17), _rand(rng, 17)
    got = mk.matmul_bias_act(
        jnp.array(x), jnp.array(w), jnp.array(b), bm=bm, bn=bn, bk=bk
    )
    want = ref.matmul_bias_act(jnp.array(x), jnp.array(w), jnp.array(b))
    _assert_close(got, want)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_bfloat16_inputs_accumulate_in_f32(seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(_rand(rng, 48, 48), jnp.bfloat16)
    w = jnp.array(_rand(rng, 48, 48), jnp.bfloat16)
    b = jnp.array(_rand(rng, 48), jnp.bfloat16)
    got = mk.matmul_bias_act(x, w, b, bm=16, bn=16, bk=16)
    want = ref.matmul_bias_act(x, w, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2, atol=5e-2,
    )


# ---------------------------------------------------------------------------
# Autodiff: the custom_vjp backward (also Pallas) vs jax.grad of the oracle.
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(act=st.sampled_from(ACTS), seed=st.integers(0, 2**31 - 1))
def test_gradients_match_oracle(act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, 9, 7), _rand(rng, 7, 5), _rand(rng, 5)
    xj, wj, bj = jnp.array(x), jnp.array(w), jnp.array(b)

    def loss_pallas(x, w, b):
        return jnp.sum(mk.matmul_bias_act(x, w, b, act=act, bm=8, bn=8, bk=8) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(ref.matmul_bias_act(x, w, b, act) ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(xj, wj, bj)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(xj, wj, bj)
    for a, c in zip(gp, gr):
        _assert_close(a, c)


def test_value_and_grad_composes_with_jit():
    rng = np.random.default_rng(7)
    x, w, b = _rand(rng, 6, 4), _rand(rng, 4, 3), _rand(rng, 3)

    @jax.jit
    def f(x, w, b):
        return jnp.mean(mk.matmul_bias_act(x, w, b, act=mk.ACT_TANH))

    v, g = jax.value_and_grad(f, argnums=1)(jnp.array(x), jnp.array(w), jnp.array(b))
    assert np.isfinite(float(v))
    assert np.all(np.isfinite(np.asarray(g)))


# ---------------------------------------------------------------------------
# Analytic perf estimators.
# ---------------------------------------------------------------------------
class TestPerfEstimators:
    def test_vmem_grows_with_blocks(self):
        assert mk.vmem_bytes(64, 64, 64) < mk.vmem_bytes(128, 128, 128)

    def test_vmem_default_fits_16mib(self):
        assert mk.vmem_bytes(mk.DEFAULT_BM, mk.DEFAULT_BN, mk.DEFAULT_BK) < 16 << 20

    def test_mxu_exact_tiling_is_full_utilization(self):
        assert mk.mxu_utilization(256, 256, 256, 128, 128, 128) == pytest.approx(1.0)

    def test_mxu_padding_penalty(self):
        # 129 rows with bm=128 pads to 256 -> about half the MACs are waste.
        u = mk.mxu_utilization(129, 128, 128, 128, 128, 128)
        assert 0.4 < u < 0.6

    def test_mxu_small_tile_occupancy_penalty(self):
        full = mk.mxu_utilization(128, 128, 128, 128, 128, 128)
        tiny = mk.mxu_utilization(128, 128, 128, 8, 8, 128)
        assert tiny < full
