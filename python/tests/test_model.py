"""Layer-2 correctness: the AOT'd model graphs vs the ref.py oracles, plus
behavioural checks (REINFORCE actually learns) that anchor the end-to-end
RL example on the Rust side."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _init_params(rng):
    return (
        rng.standard_normal((model.OBS_DIM, model.HIDDEN)).astype(np.float32) * 0.3,
        np.zeros(model.HIDDEN, np.float32),
        rng.standard_normal((model.HIDDEN, model.ACT_DIM)).astype(np.float32) * 0.3,
        np.zeros(model.ACT_DIM, np.float32),
    )


def _batch(rng):
    obs = rng.standard_normal((model.BATCH, model.OBS_DIM)).astype(np.float32)
    acts = rng.integers(0, model.ACT_DIM, model.BATCH)
    onehot = np.eye(model.ACT_DIM, dtype=np.float32)[acts]
    returns = rng.standard_normal(model.BATCH).astype(np.float32)
    return obs, onehot, returns


class TestGemm:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        x = rng.standard_normal((model.GEMM_M, model.GEMM_K)).astype(np.float32)
        w = rng.standard_normal((model.GEMM_K, model.GEMM_N)).astype(np.float32)
        b = rng.standard_normal(model.GEMM_N).astype(np.float32)
        (got,) = model.gemm(jnp.array(x), jnp.array(w), jnp.array(b))
        np.testing.assert_allclose(np.asarray(got), x @ w + b, rtol=1e-4, atol=1e-4)


class TestPolicyForward:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, seed):
        rng = np.random.default_rng(seed)
        w1, b1, w2, b2 = map(jnp.array, _init_params(rng))
        obs = jnp.array(
            rng.standard_normal((model.BATCH, model.OBS_DIM)).astype(np.float32)
        )
        (got,) = model.policy_forward(w1, b1, w2, b2, obs)
        want = ref.policy_forward(w1, b1, w2, b2, obs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_logits_shape(self):
        rng = np.random.default_rng(1)
        w1, b1, w2, b2 = map(jnp.array, _init_params(rng))
        obs = jnp.zeros((model.BATCH, model.OBS_DIM), jnp.float32)
        (logits,) = model.policy_forward(w1, b1, w2, b2, obs)
        assert logits.shape == (model.BATCH, model.ACT_DIM)


class TestPolicyStep:
    def test_matches_ref_step(self):
        rng = np.random.default_rng(2)
        params = tuple(map(jnp.array, _init_params(rng)))
        obs, onehot, returns = map(jnp.array, _batch(rng))
        got = model.policy_step(*params, obs, onehot, returns)
        want = ref.policy_step(*params, obs, onehot, returns, model.LR)
        for g, w in zip(got, want):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), rtol=5e-4, atol=5e-4
            )

    def test_loss_is_finite_scalar(self):
        rng = np.random.default_rng(3)
        params = tuple(map(jnp.array, _init_params(rng)))
        obs, onehot, returns = map(jnp.array, _batch(rng))
        out = model.policy_step(*params, obs, onehot, returns)
        loss = out[-1]
        assert loss.shape == ()
        assert np.isfinite(float(loss))

    def test_reinforce_increases_rewarded_action_prob(self):
        """One step with all-positive returns on action 0 must raise
        pi(a=0 | s) — the definitional property of the policy gradient."""
        rng = np.random.default_rng(4)
        params = tuple(map(jnp.array, _init_params(rng)))
        obs = jnp.array(
            rng.standard_normal((model.BATCH, model.OBS_DIM)).astype(np.float32)
        )
        onehot = jnp.tile(jnp.array([[1.0, 0.0]], jnp.float32), (model.BATCH, 1))
        returns = jnp.ones(model.BATCH, jnp.float32)

        def prob0(ps):
            (logits,) = model.policy_forward(*ps, obs)
            return float(jnp.mean(jax.nn.softmax(logits, axis=-1)[:, 0]))

        before = prob0(params)
        out = model.policy_step(*params, obs, onehot, returns)
        after = prob0(tuple(out[:4]))
        assert after > before

    def test_zero_returns_leave_params_fixed(self):
        rng = np.random.default_rng(5)
        params = tuple(map(jnp.array, _init_params(rng)))
        obs, onehot, _ = map(jnp.array, _batch(rng))
        out = model.policy_step(*params, obs, onehot, jnp.zeros(model.BATCH))
        for p, q in zip(params, out[:4]):
            np.testing.assert_allclose(np.asarray(p), np.asarray(q), atol=1e-7)


class TestSignalProcessing:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_fir_matches_ref(self, seed):
        rng = np.random.default_rng(seed)
        sig = jnp.array(rng.standard_normal(model.FIR_N).astype(np.float32))
        taps = jnp.array(rng.standard_normal(model.FIR_TAPS).astype(np.float32))
        (got,) = model.fir(sig, taps)
        want = ref.fir(sig, taps)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_fir_impulse_recovers_taps(self):
        sig = jnp.zeros(model.FIR_N, jnp.float32).at[0].set(1.0)
        taps = jnp.arange(model.FIR_TAPS, dtype=jnp.float32)
        (got,) = model.fir(sig, taps)
        assert float(got[0]) == pytest.approx(0.0)

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_conv2d_matches_ref(self, seed):
        rng = np.random.default_rng(seed)
        img = jnp.array(
            rng.standard_normal((model.CONV_H, model.CONV_W)).astype(np.float32)
        )
        ker = jnp.array(rng.standard_normal((3, 3)).astype(np.float32))
        (got,) = model.conv2d_3x3(img, ker)
        want = ref.conv2d_3x3(img, ker)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_conv2d_identity_kernel(self):
        rng = np.random.default_rng(6)
        img = jnp.array(
            rng.standard_normal((model.CONV_H, model.CONV_W)).astype(np.float32)
        )
        ker = jnp.zeros((3, 3), jnp.float32).at[1, 1].set(1.0)
        (got,) = model.conv2d_3x3(img, ker)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(img)[1:-1, 1:-1], rtol=1e-5, atol=1e-5
        )


class TestEntryPointRegistry:
    def test_all_entries_lower_shapes(self):
        for name, (fn, specs) in model.ENTRY_POINTS.items():
            out = jax.eval_shape(fn, *specs)
            assert isinstance(out, tuple) and len(out) >= 1, name

    def test_policy_step_output_arity(self):
        _, specs = model.ENTRY_POINTS["policy_step"]
        out = jax.eval_shape(model.policy_step, *specs)
        assert len(out) == 5
        assert out[-1].shape == ()
