//! Chaos sweep bench (EXPERIMENTS.md §Fault tolerance): crash-tolerant
//! leased sweeps must be *correct under faults* and *free without them*.
//!
//! Three acceptance gates, all asserted:
//!
//! 1. **Chaos-off byte-diff guard.** A leased sweep on a clean store (no
//!    `--chaos`) merges to a report bit-identical to the plain engine
//!    sweep — same frontier bytes, zero recovery counters, zero disk
//!    retries, no `recovery` segment in the summary. The fault hooks are
//!    invisible when disabled.
//! 2. **Chaos recovery.** Under a fixed chaos seed (torn tmp writes,
//!    rename failures, transient I/O errors, one injected worker panic,
//!    one abandoned lease) the same session still converges, the frontier
//!    stays byte-identical to the fault-free run, and every injected fault
//!    is visible in the recovery counters — no silent recovery, no abort.
//! 3. **Bounded retries.** The capped-backoff retry ladder converges: disk
//!    retries and checkpoint retries stay under fixed bounds instead of
//!    spinning.
//!
//! `cargo bench --bench chaos_sweep`

mod bench_util;

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use bench_util::{bench, fmt_ns, Table};
use windmill::arch::params::ParamGrid;
use windmill::arch::{presets, Topology};
use windmill::coordinator::{SweepEngine, SweepReport, Workload, WorkloadSuite};
use windmill::store::{DiskStore, FaultPlan, LeaseRunReport, SweepSession};

/// Fixed chaos seed for the asserted run; any seed must pass, this one is
/// pinned so CI failures reproduce with
/// `windmill sweep saxpy --store DIR --lease --chaos 0xC4A05 --worker-id 0`.
const CHAOS_SEED: u64 = 0xC4A05;
const RANGES: usize = 4;
const TTL: u64 = 4;

fn grid() -> ParamGrid {
    ParamGrid::new(presets::standard()).pea_edges(&[4, 8]).topologies(&Topology::ALL)
}

fn suite() -> WorkloadSuite {
    WorkloadSuite::single(Workload::Saxpy { n: 64 })
}

/// Fresh scratch store root (unique per call; removed by the caller).
fn scratch() -> PathBuf {
    static N: AtomicUsize = AtomicUsize::new(0);
    let n = N.fetch_add(1, Ordering::Relaxed);
    let dir =
        std::env::temp_dir().join(format!("windmill-chaosbench-{}-{n}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The exact frontier lines the CLI prints — the bytes CI diffs.
fn frontier_bytes(r: &SweepReport) -> String {
    r.frontier_points()
        .iter()
        .map(|p| {
            format!(
                "  * {:<20} {:>7.3} mm2  {:>6.2} mW  {:>9} cycles",
                p.label, p.area_mm2, p.power_mw, p.cycles
            )
        })
        .collect::<Vec<_>>()
        .join("\n")
}

fn assert_same_bits(tag: &str, a: &SweepReport, b: &SweepReport) {
    assert_eq!(a.points.len(), b.points.len(), "{tag}: point count");
    for (x, y) in a.points.iter().zip(b.points.iter()) {
        assert_eq!(x.label, y.label, "{tag}");
        assert_eq!(x.cycles, y.cycles, "{tag}: {}", x.label);
        assert_eq!(x.area_mm2.to_bits(), y.area_mm2.to_bits(), "{tag}: {}", x.label);
        assert_eq!(x.power_mw.to_bits(), y.power_mw.to_bits(), "{tag}: {}", x.label);
        assert_eq!(x.wm_time_ns.to_bits(), y.wm_time_ns.to_bits(), "{tag}: {}", x.label);
    }
    assert_eq!(a.frontier, b.frontier, "{tag}: frontier indices");
}

/// One full leased session on a fresh store; `chaos` arms the fault plan.
fn leased_run(chaos: Option<u64>) -> (SweepReport, LeaseRunReport, Arc<DiskStore>, PathBuf) {
    let dir = scratch();
    let mut store = DiskStore::open(&dir).unwrap();
    let plan = chaos.map(|s| Arc::new(FaultPlan::from_chaos_seed(s)));
    if let Some(p) = &plan {
        store = store.with_faults(p.clone());
    }
    let store = Arc::new(store);
    let engine = SweepEngine::with_store(2, store.clone());
    let (report, run) =
        SweepSession::run_leased(&engine, &grid(), &suite(), 42, 0xBE7C, RANGES, TTL)
            .expect("leased session must converge, chaos or not");
    (report, run, store, dir)
}

fn main() {
    // Fault-free unsharded baseline: the bits every arm must reproduce.
    let baseline = SweepEngine::new(2).sweep_suite(&grid(), &suite(), 42);
    assert!(baseline.failures.is_empty(), "{:?}", baseline.failures);
    let baseline_bytes = frontier_bytes(&baseline);

    // ---- gate 1: chaos off is byte-identical and counter-silent ------------
    let (clean, clean_run, clean_store, clean_dir) = leased_run(None);
    assert_same_bits("chaos-off", &clean, &baseline);
    assert_eq!(frontier_bytes(&clean), baseline_bytes, "chaos-off frontier bytes");
    assert!(!clean.recovery.any(), "clean run must report zero recovery: {:?}", clean.recovery);
    assert!(
        !clean.summary().contains("recovery"),
        "no recovery segment without faults:\n{}",
        clean.summary()
    );
    let ds = clean_store.stats();
    assert_eq!(ds.retries, 0, "no injected faults, no retries");
    assert_eq!(ds.backoff_ns, 0);
    assert_eq!(clean_run.completed, RANGES as u64);
    assert_eq!(
        (clean_run.steals, clean_run.panics, clean_run.abandoned, clean_run.checkpoint_retries),
        (0, 0, 0, 0),
        "fault hooks must be invisible when disabled"
    );
    let _ = std::fs::remove_dir_all(&clean_dir);

    // ---- gate 2: fixed-seed chaos converges to the same bytes --------------
    let plan = FaultPlan::from_chaos_seed(CHAOS_SEED);
    let n_points = grid().points().len() as u64;
    let expect_panics = u64::from(plan.panic_point().unwrap() < n_points);
    let (chaotic, chaos_run, chaos_store, chaos_dir) = leased_run(Some(CHAOS_SEED));
    assert_same_bits("chaos", &chaotic, &baseline);
    assert_eq!(frontier_bytes(&chaotic), baseline_bytes, "chaos frontier bytes");
    assert_eq!(chaos_run.completed, RANGES as u64, "every lease completed despite faults");
    assert_eq!(chaos_run.abandoned, 1, "the planned abandonment fired");
    assert_eq!(chaos_run.panics, expect_panics, "the planned panic was contained");
    assert!(chaos_run.steals >= 1, "abandoned lease was stolen back");
    assert_eq!(chaotic.recovery.steals, chaos_run.steals, "recovery visible in the merge");
    assert_eq!(chaotic.recovery.abandoned, 1);
    assert!(chaotic.summary().contains("recovery"), "{}", chaotic.summary());

    // ---- gate 3: retries are bounded, backoff is capped --------------------
    let cs = chaos_store.stats();
    // Each logical write makes at most 4 attempts (3 retries); the ladder
    // must converge rather than spin.
    assert!(
        cs.retries <= 3 * (cs.writes + cs.write_errors).max(1),
        "retry ladder diverged: {} retries over {} writes / {} errors",
        cs.retries,
        cs.writes,
        cs.write_errors
    );
    assert!(
        chaos_run.checkpoint_retries <= 12 * RANGES as u64,
        "checkpoint save ladder diverged: {}",
        chaos_run.checkpoint_retries
    );
    let _ = std::fs::remove_dir_all(&chaos_dir);

    // ---- recovery overhead table (EXPERIMENTS.md §Fault tolerance) ---------
    let clean_t = bench(1, 3, || {
        let (r, _, _, dir) = leased_run(None);
        let _ = std::fs::remove_dir_all(&dir);
        r.wall_ns
    });
    let chaos_t = bench(1, 3, || {
        let (r, _, _, dir) = leased_run(Some(CHAOS_SEED));
        let _ = std::fs::remove_dir_all(&dir);
        r.wall_ns
    });
    let ratio = chaos_t.min() / clean_t.min().max(1.0);
    let mut t = Table::new(
        "chaos sweep: leased saxpy session, 8 points x 4 ranges (cold store each run)",
        &["arm", "wall mean", "wall min", "vs clean"],
    );
    t.row(&[
        "lease, no chaos".into(),
        fmt_ns(clean_t.mean()),
        fmt_ns(clean_t.min()),
        "1.00x".into(),
    ]);
    t.row(&[
        format!("lease, chaos 0x{CHAOS_SEED:X}"),
        fmt_ns(chaos_t.mean()),
        fmt_ns(chaos_t.min()),
        format!("{ratio:.2}x"),
    ]);
    t.print();
    println!(
        "chaos recovery: {} steals, {} panics contained, {} abandoned, {} waits, \
         {} ckpt retries, {} disk retries ({} virtual backoff)",
        chaos_run.steals,
        chaos_run.panics,
        chaos_run.abandoned,
        chaos_run.waits,
        chaos_run.checkpoint_retries,
        cs.retries,
        fmt_ns(cs.backoff_ns as f64),
    );
    println!("chaos-sweep acceptance: frontier byte-identical on both arms, retries bounded");
}
