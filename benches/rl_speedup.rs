//! §VI headline: the RL training step on WindMill vs the CPU and GPU
//! baselines ("average 200× compared to CPU and 2.3× compared to GPU").
//!
//! Runs the 8-phase REINFORCE step on the cycle-accurate simulator and
//! prices the baselines with the calibrated cost models; also sweeps the
//! ablations that explain *why* the spatial array wins at this batch size
//! (CPE relaunch, ping-pong DMA, RCA-ring batching).
//!
//! `cargo bench --bench rl_speedup`

mod bench_util;

use bench_util::Table;
use windmill::arch::presets;
use windmill::compiler::compile;
use windmill::coordinator::calibrate_params;
use windmill::model::baseline::{CpuModel, GpuModel};
use windmill::plugins;
use windmill::sim::task::{ring_makespan, run_task, Phase, Task};
use windmill::util::stats::fmt_ns;
use windmill::workloads::rl;

fn rl_task(machine: &windmill::sim::MachineDesc) -> (Task, rl::RlStep) {
    let step = rl::policy_step();
    let n = step.phases.len();
    let phases: Vec<Phase> = step
        .phases
        .iter()
        .enumerate()
        .map(|(i, d)| Phase {
            mapping: std::sync::Arc::new(compile(d.clone(), machine, 42).unwrap()),
            dma_in_words: if i == 0 { (rl::BATCH * (rl::OBS + rl::ACT + 1)) as u64 } else { 0 },
            dma_out_words: if i + 1 == n { 1 } else { 0 },
        })
        .collect();
    (Task { name: "rl-step".into(), phases }, step)
}

fn run_on(params: windmill::arch::WindMillParams) -> (u64, windmill::sim::task::TaskResult, windmill::sim::MachineDesc) {
    let step = rl::policy_step();
    let params = calibrate_params(params, &step.layout);
    let machine = plugins::elaborate(params).unwrap().artifact;
    let (task, s) = rl_task(&machine);
    let mem = rl::init_image(&s, 7, machine.smem.as_ref().unwrap().words());
    let tr = run_task(&task, &machine, &mem, 8_000_000).unwrap();
    (tr.total_cycles, tr, machine)
}

fn main() {
    let (cycles, tr, machine) = run_on(presets::standard());
    let wm_ns = cycles as f64 * machine.cycle_ns();

    let step = rl::policy_step();
    let cpu_ns = CpuModel::default().time_ns(&step.op_counts());
    let gpu_ns = GpuModel::default().time_ns(
        step.flops(),
        (rl::BATCH * rl::ACT) as f64,
        step.gpu_kernels(),
        step.layout.total_words() as f64 * 4.0,
    );

    let mut t = Table::new(
        "RL step (REINFORCE, batch 64): WindMill vs baselines — paper §VI",
        &["executor", "time/step", "ratio (baseline / WindMill)", "paper"],
    );
    t.row(&["WindMill 8x8 @750MHz".into(), fmt_ns(wm_ns), "1.00x".into(), "1x".into()]);
    t.row(&[
        "CPU (VexRiscv-class in-order host)".into(),
        fmt_ns(cpu_ns),
        format!("{:.0}x", cpu_ns / wm_ns),
        "~200x".into(),
    ]);
    t.row(&[
        "GPU (small-batch launch-bound model)".into(),
        fmt_ns(gpu_ns),
        format!("{:.2}x", gpu_ns / wm_ns),
        "2.3x".into(),
    ]);
    t.print();

    println!(
        "\ncycle breakdown: compute {} | dma total {} (exposed {}) | config {} | host {}",
        tr.compute_cycles,
        tr.dma_cycles_total,
        tr.dma_cycles_exposed,
        tr.config_cycles,
        tr.host_cycles
    );

    // ---- ablations ---------------------------------------------------------
    let mut t = Table::new(
        "ablations: where the speedup comes from",
        &["variant", "cycles/step", "delta vs standard"],
    );
    t.row(&["standard (CPE + ping-pong)".into(), cycles.to_string(), "-".into()]);
    let mut p = presets::standard();
    p.cpe_enabled = false;
    let (c_nocpe, _, _) = run_on(p);
    t.row(&[
        "no CPE (host relaunch per phase)".into(),
        c_nocpe.to_string(),
        format!("{:+.1}%", 100.0 * (c_nocpe as f64 / cycles as f64 - 1.0)),
    ]);
    let mut p = presets::standard();
    p.pingpong = false;
    let (c_nopp, _, _) = run_on(p);
    t.row(&[
        "no ping-pong DMA".into(),
        c_nopp.to_string(),
        format!("{:+.1}%", 100.0 * (c_nopp as f64 / cycles as f64 - 1.0)),
    ]);
    let mut p = presets::standard();
    p.topology = windmill::arch::Topology::OneHop;
    let (c_1hop, _, _) = run_on(p);
    t.row(&[
        "1-hop interconnect".into(),
        c_1hop.to_string(),
        format!("{:+.1}%", 100.0 * (c_1hop as f64 / cycles as f64 - 1.0)),
    ]);
    t.print();

    // ---- RCA-ring batch scaling -------------------------------------------
    let mut t = Table::new(
        "RCA-ring pipelining: independent RL steps (batched agents)",
        &["tasks", "1 RCA cycles", "4-RCA ring cycles", "ring speedup"],
    );
    for n in [1u64, 4, 16, 64] {
        let single = ring_makespan(cycles, 1, n);
        let ring = ring_makespan(cycles, machine.rca_count, n);
        t.row(&[
            n.to_string(),
            single.to_string(),
            ring.to_string(),
            format!("{:.2}x", single as f64 / ring as f64),
        ]);
    }
    t.print();
}
