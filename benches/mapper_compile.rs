//! Mapper benchmarks: compile time, II quality per topology, and the
//! SCMD/MCMD context-capacity ablation (§IV-A.3).
//!
//! `cargo bench --bench mapper_compile`

mod bench_util;

use bench_util::{bench, fmt_summary, Table};
use windmill::arch::params::ExecMode;
use windmill::arch::{presets, Topology};
use windmill::compiler::compile;
use windmill::plugins;
use windmill::workloads::{linalg, rl, signal};

fn main() {
    let machine = plugins::elaborate(presets::standard()).unwrap().artifact;

    // ---- compile time & schedule quality per workload ----------------------
    let mut t = Table::new(
        "mapper: compile time and schedule quality (standard 8x8 mesh)",
        &["kernel", "nodes", "II (mem/rec/route)", "depth", "ctx words", "compile time"],
    );
    let kernels: Vec<(&str, windmill::compiler::Dfg)> = vec![
        ("saxpy-256", linalg::saxpy(256, 2.0).0),
        ("dot-256", linalg::dot(256).0),
        ("gemm-16^3", linalg::gemm_bias(16, 16, 16).0),
        ("fir-256/16", signal::fir(256, 16).0),
        ("conv3x3-32", signal::conv3x3(32, 32).0),
        ("rl-grad", rl::policy_step().phases[2].clone()),
    ];
    for (name, dfg) in kernels {
        let m = compile(dfg.clone(), &machine, 42).unwrap();
        let mut s = bench(1, 10, || compile(dfg.clone(), &machine, 42).unwrap());
        t.row(&[
            name.to_string(),
            m.dfg.nodes.len().to_string(),
            format!(
                "{} ({}/{}/{})",
                m.schedule.ii, m.schedule.ii_mem, m.schedule.ii_rec, m.schedule.ii_route
            ),
            m.schedule.depth.to_string(),
            m.schedule.ctx_words_needed.to_string(),
            fmt_summary(&mut s),
        ]);
    }
    t.print();

    // ---- topology effect on routing -----------------------------------------
    let mut t = Table::new(
        "topology ablation: routing of the RL gradient kernel",
        &["topology", "total hops", "max hops", "route II", "pipeline depth"],
    );
    for topo in Topology::ALL {
        let machine = plugins::elaborate(presets::with_topology(topo)).unwrap().artifact;
        let m = compile(rl::policy_step().phases[2].clone(), &machine, 42).unwrap();
        t.row(&[
            topo.name().to_string(),
            m.routes.total_hops().to_string(),
            m.routes.max_hops().to_string(),
            m.schedule.ii_route.to_string(),
            m.schedule.depth.to_string(),
        ]);
    }
    t.print();

    // ---- SCMD vs MCMD --------------------------------------------------------
    let mut t = Table::new(
        "SCMD vs MCMD (§IV-A.3): context capacity vs mapping freedom",
        &["mode", "effective ctx depth", "gemm maps?", "row-uniform kernel maps?"],
    );
    for mode in [ExecMode::Mcmd, ExecMode::Scmd] {
        let mut p = presets::standard();
        p.exec_mode = mode;
        let machine = plugins::elaborate(p).unwrap().artifact;
        let gemm_ok = compile(linalg::gemm_bias(8, 8, 8).0, &machine, 42).is_ok();
        // A single-op row-uniform kernel: pure streaming copy.
        let mut d = windmill::compiler::Dfg::new("copy", vec![64]);
        let x = d.load_affine(0, vec![1]);
        d.store_affine(x, 64, vec![1], 1);
        let copy_ok = compile(d, &machine, 42).is_ok();
        t.row(&[
            format!("{mode:?}"),
            machine.context_depth.to_string(),
            gemm_ok.to_string(),
            copy_ok.to_string(),
        ]);
    }
    t.print();
}
