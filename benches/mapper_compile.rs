//! Mapper benchmarks: compile time, II quality per topology, the
//! SCMD/MCMD context-capacity ablation (§IV-A.3), and the sweep engine's
//! artifact-cache speedup on repeated compiles.
//!
//! `cargo bench --bench mapper_compile`

mod bench_util;

use std::time::Instant;

use bench_util::{bench, fmt_ns, fmt_summary, Table};
use windmill::arch::params::{ExecMode, ParamGrid};
use windmill::arch::{presets, Topology};
use windmill::compiler::compile;
use windmill::coordinator::{ArtifactCache, SweepEngine, Workload};
use windmill::plugins;
use windmill::workloads::{linalg, rl, signal};

fn main() {
    let machine = plugins::elaborate(presets::standard()).unwrap().artifact;

    // ---- compile time & schedule quality per workload ----------------------
    let mut t = Table::new(
        "mapper: compile time and schedule quality (standard 8x8 mesh)",
        &["kernel", "nodes", "II (mem/rec/route)", "depth", "ctx words", "thru PEs", "compile time"],
    );
    let kernels: Vec<(&str, windmill::compiler::Dfg)> = vec![
        ("saxpy-256", linalg::saxpy(256, 2.0).0),
        ("dot-256", linalg::dot(256).0),
        ("gemm-16^3", linalg::gemm_bias(16, 16, 16).0),
        ("fir-256/16", signal::fir(256, 16).0),
        ("conv3x3-32", signal::conv3x3(32, 32).0),
        ("rl-grad", rl::policy_step().phases[2].clone()),
    ];
    for (name, dfg) in kernels {
        let m = compile(dfg.clone(), &machine, 42).unwrap();
        let mut s = bench(1, 10, || compile(dfg.clone(), &machine, 42).unwrap());
        t.row(&[
            name.to_string(),
            m.dfg.nodes.len().to_string(),
            m.schedule.brief(),
            m.schedule.depth.to_string(),
            m.schedule.ctx_words_needed.to_string(),
            m.routes.through_pes().to_string(),
            fmt_summary(&mut s),
        ]);
    }
    t.print();

    // ---- topology effect on routing -----------------------------------------
    let mut t = Table::new(
        "topology ablation: routing of the RL gradient kernel",
        &["topology", "total hops", "max hops", "route II", "pipeline depth"],
    );
    for topo in Topology::ALL {
        let machine = plugins::elaborate(presets::with_topology(topo)).unwrap().artifact;
        let m = compile(rl::policy_step().phases[2].clone(), &machine, 42).unwrap();
        t.row(&[
            topo.name().to_string(),
            m.routes.total_hops().to_string(),
            m.routes.max_hops().to_string(),
            m.schedule.ii_route.to_string(),
            m.schedule.depth.to_string(),
        ]);
    }
    t.print();

    // ---- SCMD vs MCMD --------------------------------------------------------
    let mut t = Table::new(
        "SCMD vs MCMD (§IV-A.3): context capacity vs mapping freedom",
        &["mode", "effective ctx depth", "gemm maps?", "row-uniform kernel maps?"],
    );
    for mode in [ExecMode::Mcmd, ExecMode::Scmd] {
        let mut p = presets::standard();
        p.exec_mode = mode;
        let machine = plugins::elaborate(p).unwrap().artifact;
        let gemm_ok = compile(linalg::gemm_bias(8, 8, 8).0, &machine, 42).is_ok();
        // A single-op row-uniform kernel: pure streaming copy.
        let mut d = windmill::compiler::Dfg::new("copy", vec![64]);
        let x = d.load_affine(0, vec![1]);
        d.store_affine(x, 64, vec![1], 1);
        let copy_ok = compile(d, &machine, 42).is_ok();
        t.row(&[
            format!("{mode:?}"),
            machine.context_depth.to_string(),
            gemm_ok.to_string(),
            copy_ok.to_string(),
        ]);
    }
    t.print();

    // ---- artifact cache: cold vs warm compile on a shared workload ---------
    // A DSE sweep recompiles the same kernel whenever points repeat an
    // architecture/seed pair (iterated grids, repeated studies). The cache
    // answers the second compile from the store; the acceptance bar for
    // this repo is a ≥2x cache-hit speedup, which the assert pins.
    let cache = ArtifactCache::new();
    let params = presets::standard();
    let elab = cache.machine(&params).unwrap();
    let kernels: Vec<(&str, windmill::compiler::Dfg)> = vec![
        ("saxpy-256", linalg::saxpy(256, 2.0).0),
        ("gemm-16^3", linalg::gemm_bias(16, 16, 16).0),
        ("conv3x3-32", signal::conv3x3(32, 32).0),
    ];
    let mut t = Table::new(
        "artifact cache: cold miss vs warm hit (same arch x kernel x seed)",
        &["kernel", "cold compile", "warm lookup", "speedup"],
    );
    let mut worst_speedup = f64::INFINITY;
    for (name, dfg) in &kernels {
        let t0 = Instant::now();
        let (_, _, hit0) = cache.mapping(&params, dfg, &elab.machine, 42).unwrap();
        let cold_ns = t0.elapsed().as_nanos() as f64;
        assert!(!hit0, "{name}: first compile must be a miss");

        // Median of several warm lookups (they are sub-microsecond).
        let mut warm = bench(2, 20, || {
            let (_, _, hit) = cache.mapping(&params, dfg, &elab.machine, 42).unwrap();
            assert!(hit, "{name}: second compile must report a cache hit");
        });
        let warm_ns = warm.p50();
        let speedup = cold_ns / warm_ns.max(1.0);
        worst_speedup = worst_speedup.min(speedup);
        t.row(&[
            name.to_string(),
            fmt_ns(cold_ns),
            fmt_ns(warm_ns),
            format!("{speedup:.0}x"),
        ]);
    }
    t.print();
    assert!(
        worst_speedup >= 2.0,
        "cache-hit speedup {worst_speedup:.2}x is below the 2x acceptance bar"
    );
    println!("cache-hit speedup ≥ 2x confirmed (worst case {worst_speedup:.0}x)");

    // ---- sweep-level view: a grid sharing the workload dimension -----------
    // Every point of this smem sweep compiles the same GEMM; re-running the
    // sweep on the warm engine turns all elaborations and compiles into
    // hits.
    let engine = SweepEngine::new(1);
    let grid = ParamGrid::new(presets::standard()).smem_geoms(&[(16, 256), (16, 512), (32, 512)]);
    let wl = Workload::Gemm { m: 16, n: 16, k: 16 };
    let cold = engine.sweep(&grid, &wl);
    let warm = engine.sweep(&grid, &wl);
    println!(
        "\nsmem sweep (shared GEMM workload): cold {} | warm {}",
        cold.summary(),
        warm.summary()
    );
    assert!(warm.cache_hit_rate() > 0.99, "warm sweep must be all hits");
}
