//! Simulator hot-loop throughput: the optimized engine
//! (`windmill::sim::engine`) vs the frozen pre-refactor baseline
//! (`windmill::sim::reference`) on a GEMM-style loop nest.
//!
//! Both engines execute the *same* mapping against the *same* image and —
//! by construction, pinned by `tests/engine_equivalence.rs` — produce the
//! same cycle count, so the ratio of wall times is a pure measure of the
//! hot-loop overhaul (calendar queue, CSR consumers, fixed operand reads,
//! active worklist, reusable response buffer). Acceptance bar: ≥ 3×
//! simulated-cycles/sec on the GEMM nest.
//!
//! Prints EXPERIMENTS.md §Perf-ready rows. `cargo bench --bench sim_throughput`

mod bench_util;

use std::time::Instant;

use bench_util::Table;
use windmill::arch::presets;
use windmill::compiler::{compile, Mapping};
use windmill::plugins;
use windmill::sim::engine::simulate;
use windmill::sim::reference::simulate_reference;
use windmill::sim::{MachineDesc, SimResult};
use windmill::util::Rng;
use windmill::workloads::linalg;

struct Measured {
    cycles: u64,
    fires: u64,
    /// Median wall nanoseconds per full simulation.
    wall_ns: f64,
}

fn measure(
    name: &str,
    reps: usize,
    mapping: &Mapping,
    machine: &MachineDesc,
    image: &[f32],
    run: impl Fn(&Mapping, &MachineDesc, &[f32]) -> SimResult,
) -> Measured {
    // Warmup.
    let first = std::hint::black_box(run(mapping, machine, image));
    let mut walls: Vec<f64> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = std::hint::black_box(run(mapping, machine, image));
        walls.push(t0.elapsed().as_nanos() as f64);
        assert_eq!(r.cycles, first.cycles, "{name}: nondeterministic sim");
    }
    walls.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Measured { cycles: first.cycles, fires: first.fires, wall_ns: walls[reps / 2] }
}

fn rate(per_run: f64, wall_ns: f64) -> f64 {
    per_run / (wall_ns / 1e9)
}

fn fmt_rate(r: f64) -> String {
    if r >= 1e6 {
        format!("{:.2} M/s", r / 1e6)
    } else {
        format!("{:.1} k/s", r / 1e3)
    }
}

fn main() {
    let machine = plugins::elaborate(presets::standard()).unwrap().artifact;
    let words = machine.smem.as_ref().unwrap().words();
    let mut rng = Rng::new(11);

    // The workloads: the Fig.6-style GEMM nest (acceptance kernel) plus a
    // long 1-D SFU pipeline (latency/calendar stress).
    let (gemm, gl) = linalg::gemm_bias(16, 16, 16);
    let gemm_map = compile(gemm, &machine, 42).unwrap();
    let mut gemm_img = vec![0.0f32; words];
    for w in gemm_img.iter_mut().take(gl.total_words() as usize) {
        *w = rng.normal();
    }

    let (fir, fl) = windmill::workloads::signal::fir(256, 16);
    let fir_map = compile(fir, &machine, 42).unwrap();
    let mut fir_img = vec![0.0f32; words];
    for w in fir_img.iter_mut().take(fl.total_words() as usize) {
        *w = rng.normal();
    }

    let reps = 15;
    let mut t = Table::new(
        "cycle-accurate engine throughput: optimized vs pre-refactor reference",
        &["kernel", "engine", "sim cycles", "cycles/s", "PE fires/s", "wall/run"],
    );
    let mut gemm_speedup = 0.0;
    for (name, mapping, image) in
        [("gemm-16^3", &gemm_map, &gemm_img), ("fir-256t16", &fir_map, &fir_img)]
    {
        let fast = measure(name, reps, mapping, &machine, image, |m, mc, img| {
            simulate(m, mc, img, 8_000_000).unwrap()
        });
        let slow = measure(name, reps, mapping, &machine, image, |m, mc, img| {
            simulate_reference(m, mc, img, 8_000_000).unwrap()
        });
        assert_eq!(fast.cycles, slow.cycles, "{name}: engines disagree on cycles");
        assert_eq!(fast.fires, slow.fires, "{name}: engines disagree on fires");
        for (engine, m) in [("optimized", &fast), ("reference", &slow)] {
            t.row(&[
                name.to_string(),
                engine.to_string(),
                m.cycles.to_string(),
                fmt_rate(rate(m.cycles as f64, m.wall_ns)),
                fmt_rate(rate(m.fires as f64, m.wall_ns)),
                format!("{:.2} ms", m.wall_ns / 1e6),
            ]);
        }
        let speedup = slow.wall_ns / fast.wall_ns;
        println!(
            "| {name} | {} | {} | {speedup:.2}x |   <- EXPERIMENTS.md §Perf row",
            fmt_rate(rate(slow.cycles as f64, slow.wall_ns)),
            fmt_rate(rate(fast.cycles as f64, fast.wall_ns)),
        );
        if name == "gemm-16^3" {
            gemm_speedup = speedup;
        }
    }
    t.print();

    assert!(
        gemm_speedup >= 3.0,
        "acceptance: optimized engine must deliver >= 3x simulated-cycles/sec \
         over the pre-refactor engine on the GEMM nest (got {gemm_speedup:.2}x)"
    );
    println!("simulator hot-loop speedup >= 3x confirmed ({gemm_speedup:.2}x on gemm-16^3)");
}
