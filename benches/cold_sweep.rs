//! Cold-sweep bench (PR 4): stage-granular compile memoization on a
//! context-depth grid, and the engine's event-driven cycle-skip counter.
//!
//! The warm path has been free since PR 2/3; this bench pins the **cold**
//! path — the first sweep over a fresh grid, which is what the paper's
//! Fig. 6 scalability experiment and every new application demand actually
//! exercise. Two headline numbers:
//!
//! 1. A cold sweep over a grid varying only context depth performs exactly
//!    one place and one route per `(kernel, seed)` — `(N-1)/N` of the
//!    place+route work vanishes — and its summed compile wall time beats
//!    the monolithic (stage-memoization-off) baseline (asserted).
//! 2. On a stall-heavy SFU chain the engine reports >0 skipped cycles
//!    (asserted) while staying cycle-identical to the reference engine
//!    (pinned separately in `tests/engine_equivalence.rs`).
//!
//! `cargo bench --bench cold_sweep`

mod bench_util;

use std::sync::Arc;

use bench_util::{fmt_ns, Table};
use windmill::arch::isa::Op;
use windmill::arch::params::ParamGrid;
use windmill::arch::presets;
use windmill::compiler::{compile, Dfg};
use windmill::coordinator::{ArtifactCache, SweepEngine, Workload};
use windmill::plugins;
use windmill::sim::engine::simulate_counting;

fn ctx_grid() -> ParamGrid {
    // All depths at or above the standard 32: every point is mappable, so
    // the two paths run the identical point set.
    ParamGrid::new(presets::standard()).context_depths(&[32, 48, 64, 96, 128, 256])
}

fn main() {
    let wl = Workload::Fir { n: 128, taps: 12 };

    // Single worker on both sides: the comparison is work done, not
    // scheduling luck, and with one worker the stage-miss counts are exact.
    let staged = SweepEngine::new(1).sweep(&ctx_grid(), &wl);
    assert!(staged.failures.is_empty(), "{:?}", staged.failures);
    let mono_cache = Arc::new(ArtifactCache::new().with_stage_memo(false));
    let mono = SweepEngine::with_cache(1, mono_cache).sweep(&ctx_grid(), &wl);
    assert!(mono.failures.is_empty(), "{:?}", mono.failures);

    let n = staged.points.len() as u64;
    let place = staged.cache.pass_counts_full("place");
    let route = staged.cache.pass_counts_full("route");
    assert_eq!(place.miss, 1, "cold context-depth sweep must place exactly once");
    assert_eq!(route.miss, 1, "cold context-depth sweep must route exactly once");
    assert_eq!(place.mem, n - 1, "every other point reuses the placement");
    assert_eq!(staged.cache.pass_counts_full("schedule").miss, n);

    let mut t = Table::new(
        "cold context-depth sweep: stage-memoized vs monolithic compile",
        &["path", "points", "compile wall", "place lookups (m/d/x)", "reuse"],
    );
    t.row(&[
        "stage-memoized".into(),
        staged.points.len().to_string(),
        fmt_ns(staged.timing.compile_ns as f64),
        format!("{}m/{}d/{}x", place.mem, place.disk, place.miss),
        format!("{:.0}%", 100.0 * staged.place_route_reuse()),
    ]);
    let mono_place = mono.cache.pass_counts_full("place");
    t.row(&[
        "monolithic".into(),
        mono.points.len().to_string(),
        fmt_ns(mono.timing.compile_ns as f64),
        format!("{}m/{}d/{}x", mono_place.mem, mono_place.disk, mono_place.miss),
        "-".into(),
    ]);
    t.print();
    println!("staged summary: {}", staged.summary());

    let speedup = mono.timing.compile_ns as f64 / staged.timing.compile_ns.max(1) as f64;
    println!(
        "cold compile wall: monolithic {} vs staged {} ({speedup:.2}x)",
        fmt_ns(mono.timing.compile_ns as f64),
        fmt_ns(staged.timing.compile_ns as f64),
    );
    assert!(
        staged.timing.compile_ns < mono.timing.compile_ns,
        "stage-memoized cold sweep must beat the monolithic path: {} vs {} ns",
        staged.timing.compile_ns,
        mono.timing.compile_ns
    );

    // Results are bit-identical either way (also pinned by
    // tests/stage_memoization.rs; cheap to re-assert here).
    for (a, b) in staged.points.iter().zip(mono.points.iter()) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.cycles, b.cycles, "{}", a.label);
        assert_eq!(a.wm_time_ns.to_bits(), b.wm_time_ns.to_bits(), "{}", a.label);
    }

    // ---- engine cycle skipping on a stall-heavy SFU chain ------------------
    let machine = plugins::elaborate(presets::standard()).unwrap().artifact;
    let mut d = Dfg::new("sfu-stall", vec![2]);
    let mut v = d.load_affine(0, vec![1]);
    for i in 0..8 {
        v = d.unary(if i % 2 == 0 { Op::Tanh } else { Op::Exp }, v);
    }
    d.store_affine(v, 64, vec![1], 1);
    let mapping = compile(d, &machine, 42).unwrap();
    let image = vec![0.2f32; 128];
    let (res, skipped) = simulate_counting(&mapping, &machine, &image, 1_000_000).unwrap();
    println!(
        "sfu-stall chain: {} cycles, {} skipped ({:.0}% never ticked)",
        res.cycles,
        skipped,
        100.0 * skipped as f64 / res.cycles as f64
    );
    assert!(skipped > 0, "stall-heavy chain must skip cycles");
    println!("cold-sweep acceptance: staged beats monolithic, cycle skip engaged");
}
