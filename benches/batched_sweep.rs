//! Batched-sweep bench (PR 6): the lockstep simulation arena on a cold
//! same-DFG grid, against per-point dispatch.
//!
//! A design-space grid that varies architecture parameters but not the
//! kernel runs the *same* DFG at every point; the [`windmill::sim::SimArena`]
//! decodes that DFG's skeleton (validation, CSR adjacency, node-state
//! template) once per launch and steps the points as independent lanes.
//! Three claims, all asserted:
//!
//! 1. The batched cold sweep is **bit-identical** to per-point dispatch —
//!    every point, every column, plus the skipped-cycle totals.
//! 2. Batching actually batches: a 16-point grid at `--batch 8` performs
//!    exactly 2 arena launches at 8.0 lanes/launch (the report's occupancy
//!    counters), where per-point dispatch enters the engine 16 times.
//! 3. On equal work — the same 16 lanes — one arena launch beats 16 solo
//!    engine runs (min over repetitions; the margin is the 15 redundant
//!    skeleton decodes).
//!
//! `cargo bench --bench batched_sweep`

mod bench_util;

use bench_util::{bench, fmt_ns, Table};
use windmill::arch::isa::Op;
use windmill::arch::params::ParamGrid;
use windmill::arch::presets;
use windmill::compiler::{compile, Dfg};
use windmill::coordinator::{SweepEngine, SweepReport, Workload};
use windmill::plugins;
use windmill::sim::{simulate_batch, simulate_counting, LaneSpec};

/// 16 context depths at or above the standard 32: every point is mappable,
/// every point runs the identical kernel DFG, and 16 divides evenly into
/// two batch-8 chunks.
fn ctx_grid() -> ParamGrid {
    ParamGrid::new(presets::standard()).context_depths(&[
        32, 40, 48, 56, 64, 72, 80, 88, 96, 104, 112, 120, 128, 160, 192, 256,
    ])
}

fn point_key(r: &SweepReport) -> Vec<(String, u64, u64, u64)> {
    r.points
        .iter()
        .map(|p| (p.label.clone(), p.cycles, p.wm_time_ns.to_bits(), p.area_mm2.to_bits()))
        .collect()
}

fn main() {
    let wl = Workload::Fir { n: 128, taps: 12 };

    // ---- cold sweep: batched arena dispatch vs per-point dispatch ----------
    // Single worker on both sides so the comparison is work done, not
    // scheduling luck, and the launch/occupancy counters are exact.
    let batched = SweepEngine::new(1).with_batch(8).sweep(&ctx_grid(), &wl);
    assert!(batched.failures.is_empty(), "{:?}", batched.failures);
    let unbatched = SweepEngine::new(1).with_batch(1).sweep(&ctx_grid(), &wl);
    assert!(unbatched.failures.is_empty(), "{:?}", unbatched.failures);

    // (1) Bit-identical reports.
    assert_eq!(point_key(&batched), point_key(&unbatched), "batching changed a result");
    assert_eq!(batched.frontier, unbatched.frontier);
    assert_eq!(
        batched.timing.sim_skipped_cycles, unbatched.timing.sim_skipped_cycles,
        "per-lane event skip must be dispatch-invariant"
    );

    // (2) The occupancy counters: 16 cold points in two full 8-lane
    // launches; per-point dispatch never launches an arena.
    assert_eq!(batched.timing.batch_launches, 2, "{:?}", batched.timing);
    assert_eq!(batched.timing.batch_lanes, 16, "{:?}", batched.timing);
    assert_eq!(unbatched.timing.batch_launches, 0, "{:?}", unbatched.timing);
    let occupancy =
        batched.timing.batch_lanes as f64 / batched.timing.batch_launches as f64;

    let mut t = Table::new(
        "cold 16-point same-DFG sweep: arena dispatch vs per-point",
        &["path", "engine entries", "lanes/launch", "sim wall", "sweep wall"],
    );
    t.row(&[
        "batched (8)".into(),
        batched.timing.batch_launches.to_string(),
        format!("{occupancy:.1}"),
        fmt_ns(batched.timing.simulate_ns as f64),
        fmt_ns(batched.wall_ns as f64),
    ]);
    t.row(&[
        "per-point".into(),
        "16".into(),
        "1.0".into(),
        fmt_ns(unbatched.timing.simulate_ns as f64),
        fmt_ns(unbatched.wall_ns as f64),
    ]);
    t.print();
    println!("batched summary: {}", batched.summary());

    // ---- equal-work microbench: one launch vs 16 solo engine runs ----------
    // A decode-heavy, short-running kernel so the shared skeleton is a
    // visible fraction of each run; 16 lanes differ by memory image.
    let machine = plugins::elaborate(presets::standard()).unwrap().artifact;
    let words = machine.smem.as_ref().unwrap().words();
    let mut d = Dfg::new("chain", vec![2]);
    let mut v = d.load_affine(0, vec![1]);
    for i in 0..8 {
        v = d.unary(if i % 2 == 0 { Op::Abs } else { Op::Neg }, v);
    }
    d.store_affine(v, 64, vec![1], 1);
    let mapping = compile(d, &machine, 42).unwrap();
    let images: Vec<Vec<f32>> = (0..16)
        .map(|l| {
            let mut img = vec![0.0f32; words];
            for (i, w) in img.iter_mut().take(32).enumerate() {
                *w = (l * 31 + i) as f32 * 0.125 - 2.0;
            }
            img
        })
        .collect();
    let lanes: Vec<LaneSpec> = images
        .iter()
        .map(|img| LaneSpec { mapping: &mapping, machine: &machine, image: img })
        .collect();

    // Equal-work identity first (also pinned in tests/engine_equivalence.rs).
    let arena_out = simulate_batch(&lanes, 1_000_000);
    for (l, out) in arena_out.iter().enumerate() {
        let (r, skipped) = out.as_ref().unwrap();
        let (solo, solo_skipped) =
            simulate_counting(&mapping, &machine, &images[l], 1_000_000).unwrap();
        assert_eq!(r.cycles, solo.cycles, "lane {l}");
        assert_eq!(*skipped, solo_skipped, "lane {l}");
        for (i, (a, b)) in r.mem.iter().zip(solo.mem.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "lane {l} mem[{i}]");
        }
    }

    let mut arena = bench(5, 40, || simulate_batch(&lanes, 1_000_000));
    let mut solo = bench(5, 40, || {
        lanes
            .iter()
            .map(|l| simulate_counting(l.mapping, l.machine, l.image, 1_000_000))
            .collect::<Vec<_>>()
    });
    let speedup = solo.min() / arena.min();
    println!(
        "16 lanes, equal work: arena {} vs 16 solo runs {} ({speedup:.2}x, min of 40)",
        fmt_ns(arena.min()),
        fmt_ns(solo.min()),
    );
    assert!(
        arena.min() < solo.min(),
        "one arena launch must beat 16 solo engine runs: {} vs {} ns",
        arena.min(),
        solo.min()
    );
    println!(
        "batched-sweep acceptance: bit-identical at {occupancy:.1} lanes/launch, arena beats solo"
    );
}
