//! §IV-A.4 ablations: the PAI round-robin arbiter under bank contention,
//! and the ping-pong DMA's migration/compute overlap.
//!
//! `cargo bench --bench smem_contention`

mod bench_util;

use bench_util::Table;
use windmill::arch::presets;
use windmill::compiler::{compile, Dfg};
use windmill::plugins;
use windmill::sim::engine::simulate;
use windmill::sim::task::{run_task, Phase, Task};

/// k parallel load streams with a given stride (stride 16 on a 16-bank
/// memory pins every stream to one bank; stride 1 rotates conflict-free).
fn streams(k: usize, stride: i32, iters: u32) -> Dfg {
    let mut d = Dfg::new("streams", vec![iters]);
    let mut acc = None;
    for s in 0..k {
        let x = d.load_affine(s as u32, vec![stride]);
        acc = Some(match acc {
            None => x,
            Some(a) => d.compute(windmill::arch::isa::Op::Add, a, x),
        });
    }
    d.store_affine(acc.unwrap(), 8000, vec![1], 1);
    d
}

fn main() {
    let params = presets::with_smem(16, 1024);
    let machine = plugins::elaborate(params).unwrap().artifact;
    let words = machine.smem.as_ref().unwrap().words();
    let mem = vec![1.0f32; words];

    // ---- bank-conflict sweep ----------------------------------------------
    let mut t = Table::new(
        "PAI round-robin arbiter under bank contention (16 banks, 64 iters)",
        &["load streams", "stride", "cycles", "conflict cycles", "measured II"],
    );
    for &k in &[2usize, 4, 8] {
        for &stride in &[1i32, 16] {
            let d = streams(k, stride, 64);
            let m = compile(d, &machine, 5).unwrap();
            let r = simulate(&m, &machine, &mem, 4_000_000).unwrap();
            t.row(&[
                k.to_string(),
                format!("{stride} ({})", if stride % 16 == 0 { "bank-pinned" } else { "rotating" }),
                r.cycles.to_string(),
                r.smem.conflicts.to_string(),
                format!("{:.2}", r.measured_ii),
            ]);
        }
    }
    t.print();

    // ---- ping-pong DMA overlap ---------------------------------------------
    let build_task = |machine: &windmill::sim::MachineDesc| -> Task {
        let phases = (0..4)
            .map(|i| {
                let mut d = Dfg::new("ph", vec![256]);
                let x = d.load_affine(0, vec![1]);
                let y = d.unary(windmill::arch::isa::Op::Mul, x);
                d.store_affine(y, 4096 + i * 256, vec![1], 1);
                Phase {
                    mapping: std::sync::Arc::new(compile(d, machine, 9).unwrap()),
                    dma_in_words: 2048,
                    dma_out_words: 256,
                }
            })
            .collect();
        Task { name: "pp".into(), phases }
    };
    let mut t = Table::new(
        "ping-pong DMA: 4 phases x 2048-word migrations",
        &["variant", "total cycles", "dma total", "dma exposed", "hidden %"],
    );
    for pingpong in [true, false] {
        let mut p = presets::with_smem(16, 1024);
        p.pingpong = pingpong;
        let machine = plugins::elaborate(p).unwrap().artifact;
        let task = build_task(&machine);
        let mem = vec![1.0f32; machine.smem.as_ref().unwrap().words()];
        let r = run_task(&task, &machine, &mem, 4_000_000).unwrap();
        let hidden = 100.0 * (1.0 - r.dma_cycles_exposed as f64 / r.dma_cycles_total.max(1) as f64);
        t.row(&[
            if pingpong { "ping-pong (MSB flip)" } else { "serial DMA" }.to_string(),
            r.total_cycles.to_string(),
            r.dma_cycles_total.to_string(),
            r.dma_cycles_exposed.to_string(),
            format!("{hidden:.0}%"),
        ]);
    }
    t.print();
}
