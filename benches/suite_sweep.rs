//! Suite-sweep bench (PR 5): the paper's three workload aspects evaluated
//! per grid point through one engine, with the compile reuse the suite
//! path is supposed to buy made observable.
//!
//! Headline numbers:
//!
//! 1. A cold **suite** sweep ({gemm, spmv, rl-step} — linear algebra,
//!    non-affine signal-style gather, RL) over a context-depth grid
//!    performs place/route exactly **once per kernel** across the whole
//!    suite (10 kernels: 1 + 1 + 8 RL phases), and one elaboration per
//!    grid point regardless of suite size (asserted).
//! 2. A warm re-run of the whole suite performs zero `simulate()` calls
//!    (asserted), i.e. suite evaluation composes with every cache tier.
//! 3. The per-workload columns and the (area, power, per-workload times)
//!    frontier come out of the same run — the cross-scenario comparison
//!    the ROADMAP's multi-workload item asked for, in one report.
//!
//! `cargo bench --bench suite_sweep`

mod bench_util;

use bench_util::{fmt_ns, Table};
use windmill::arch::params::ParamGrid;
use windmill::arch::presets;
use windmill::coordinator::{SweepEngine, Workload, WorkloadSuite};

fn ctx_grid() -> ParamGrid {
    ParamGrid::new(presets::standard()).context_depths(&[32, 48, 64, 128])
}

fn main() {
    let suite = WorkloadSuite::new(vec![
        Workload::Gemm { m: 16, n: 16, k: 16 },
        Workload::Spmv { rows: 32, cols: 48, k: 4 },
        Workload::RlStep,
    ])
    .unwrap();
    let n_kernels: u64 = suite.workloads().iter().map(|w| w.build().0.len() as u64).sum();

    // Single worker: stage lookups are sequential, so the counts are exact.
    let engine = SweepEngine::new(1);
    let t0 = std::time::Instant::now();
    let cold = engine.sweep_suite(&ctx_grid(), &suite, 42);
    let cold_wall = t0.elapsed().as_nanos() as f64;
    assert!(cold.failures.is_empty(), "{:?}", cold.failures);
    let points = cold.points.len() as u64;

    let place = cold.cache.pass_counts_full("place");
    let route = cold.cache.pass_counts_full("route");
    assert_eq!(place.miss, n_kernels, "one placement per kernel, suite-wide");
    assert_eq!(route.miss, n_kernels, "one routing per kernel, suite-wide");
    assert_eq!(place.mem, n_kernels * (points - 1), "every other point reuses");
    let elab = cold.cache.pass_counts_full("elaborate");
    assert_eq!(elab.miss, points, "one elaboration per point, not per member");
    assert_eq!(elab.mem, points * (suite.len() as u64 - 1));

    let t0 = std::time::Instant::now();
    let warm = engine.sweep_suite(&ctx_grid(), &suite, 42);
    let warm_wall = t0.elapsed().as_nanos() as f64;
    assert_eq!(warm.cache.pass_counts_full("simulate").miss, 0, "warm suite re-simulated");
    assert_eq!(warm.sim_hit_rate(), 1.0);

    let mut t = Table::new(
        "suite sweep {gemm, spmv, rl-step} on the context-depth grid",
        &["run", "points", "wall", "place (m/d/x)", "p/r reuse", "sim hit"],
    );
    for (name, r, wall) in [("cold", &cold, cold_wall), ("warm", &warm, warm_wall)] {
        let p = r.cache.pass_counts_full("place");
        t.row(&[
            name.into(),
            r.points.len().to_string(),
            fmt_ns(wall),
            format!("{}m/{}d/{}x", p.mem, p.disk, p.miss),
            format!("{:.0}%", 100.0 * r.place_route_reuse()),
            format!("{:.0}%", 100.0 * r.sim_hit_rate()),
        ]);
    }
    t.print();

    // The suite columns: every point carries one row per member, and the
    // frontier is computed over the per-workload time vector.
    let names = cold.workload_names();
    assert_eq!(names.len(), 3);
    let mut cols = Table::new(
        "per-workload time columns (geomean over grid points)",
        &["workload", "geomean time", "best point"],
    );
    for (i, name) in names.iter().enumerate() {
        let best = cold
            .points
            .iter()
            .min_by(|a, b| {
                a.per_workload[i].wm_time_ns.total_cmp(&b.per_workload[i].wm_time_ns)
            })
            .unwrap();
        cols.row(&[
            name.clone(),
            fmt_ns(cold.geomean_time(i)),
            best.label.clone(),
        ]);
    }
    cols.print();
    println!("{}", cold.summary());
    assert!(!cold.frontier.is_empty());
    assert_eq!(cold.rejected_nonfinite, 0);
    println!(
        "suite-sweep acceptance: {n_kernels} kernels placed/routed once, warm suite free"
    );
}
