//! Adaptive-DSE bench (PR 7): search-guided exploration vs the exhaustive
//! grid, on a Fig. 6-style context-depth × PEA-size grid whose Pareto
//! frontier is known analytically.
//!
//! For saxpy-64 every context depth ≥ 32 leaves the engine's iteration
//! window unbound, so cycles are identical along each depth chain while
//! area and power grow strictly with depth — the exhaustive frontier
//! collapses onto the minimum-depth column. A driver that starts from a
//! stratified sample and refines toward smaller coordinates must therefore
//! recover the exact frontier while touching a fraction of the grid.
//! Headline assertions:
//!
//! 1. [`SuccessiveHalving`] under a hard budget evaluates **≤ 50%** of the
//!    72-point grid;
//! 2. its frontier is dominance-equivalent to the exhaustive one;
//! 3. the drive is deterministic for a fixed seed;
//! 4. its cold wall time beats the exhaustive cold sweep's.
//!
//! `cargo bench --bench adaptive_dse`

mod bench_util;

use bench_util::{fmt_ns, Table};
use windmill::arch::params::ParamGrid;
use windmill::arch::presets;
use windmill::coordinator::{SuccessiveHalving, SweepEngine, SweepReport, Workload, WorkloadSuite};

fn grid() -> ParamGrid {
    // 3 PEA edges × 24 context depths (all ≥ the standard 32) = 72 points.
    let depths: Vec<usize> = (0..24).map(|i| 32 + 16 * i).collect();
    ParamGrid::new(presets::standard()).pea_edges(&[4, 6, 8]).context_depths(&depths)
}

fn drive_once(grid: &ParamGrid, suite: &WorkloadSuite, budget: usize) -> SweepReport {
    let mut driver = SuccessiveHalving::new(grid, 42).with_budget(budget);
    SweepEngine::new(4).drive(grid, suite, 42, &mut driver)
}

fn main() {
    let grid = grid();
    let n = grid.len();
    assert_eq!(n, 72, "bench grid must be the 72-point ctx x edge grid");
    let suite = WorkloadSuite::single(Workload::Saxpy { n: 64 });
    let budget = n / 2;

    // Exhaustive cold sweep: the baseline every driver must beat.
    let exhaustive = SweepEngine::new(4).sweep_suite(&grid, &suite, 42);
    assert!(exhaustive.failures.is_empty(), "{:?}", exhaustive.failures);

    // Search-guided cold drive on a fresh engine (nothing shared).
    let driven = drive_once(&grid, &suite, budget);
    assert!(driven.failures.is_empty(), "{:?}", driven.failures);

    let mut t = Table::new(
        "adaptive DSE vs exhaustive sweep (saxpy-64, 72-point ctx x edge grid)",
        &["path", "evaluated", "fraction", "frontier", "wall"],
    );
    for (name, r) in [("exhaustive", &exhaustive), ("halving drive", &driven)] {
        t.row(&[
            name.into(),
            r.points_evaluated().to_string(),
            format!("{:.1}%", 100.0 * r.points_evaluated() as f64 / n as f64),
            r.frontier.len().to_string(),
            fmt_ns(r.wall_ns as f64),
        ]);
    }
    t.print();
    println!("driven summary: {}", driven.summary());

    // 1. Budget respected: at most half the grid was ever evaluated.
    assert!(
        driven.points_evaluated() * 2 <= n,
        "driver must evaluate <= 50% of the grid: {}/{n}",
        driven.points_evaluated()
    );
    assert!(driven.summary().contains("searched"), "{}", driven.summary());

    // 2. Dominance-equivalence with the exhaustive frontier, both ways
    //    (halving only proposes grid points, so neither side may hold a
    //    point the other fails to match or dominate).
    let covers = |xs: &SweepReport, e: &windmill::coordinator::SweepPoint| {
        xs.frontier_points().iter().any(|d| d.arch_hash == e.arch_hash || d.dominates(e))
    };
    for e in exhaustive.frontier_points() {
        assert!(covers(&driven, e), "exhaustive frontier point `{}` missed", e.label);
    }
    for d in driven.frontier_points() {
        assert!(covers(&exhaustive, d), "driven frontier point `{}` is spurious", d.label);
    }

    // 3. Fixed seed => reproducible search trajectory.
    let again = drive_once(&grid, &suite, budget);
    let labels = |r: &SweepReport| r.points.iter().map(|p| p.label.clone()).collect::<Vec<_>>();
    assert_eq!(labels(&driven), labels(&again), "drive must be deterministic");
    assert_eq!(
        driven.frontier_points().iter().map(|p| &p.label).collect::<Vec<_>>(),
        again.frontier_points().iter().map(|p| &p.label).collect::<Vec<_>>(),
    );

    // 4. Half the evaluations, less wall time (both cold, same machine).
    assert!(
        driven.wall_ns < exhaustive.wall_ns,
        "cold drive must beat the cold exhaustive sweep: {} vs {} ns",
        driven.wall_ns,
        exhaustive.wall_ns
    );
    println!(
        "adaptive-dse acceptance: {} of {n} points ({} frontier) in {} vs exhaustive {}",
        driven.points_evaluated(),
        driven.frontier.len(),
        fmt_ns(driven.wall_ns as f64),
        fmt_ns(exhaustive.wall_ns as f64),
    );
}
