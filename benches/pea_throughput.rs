//! Cross-domain capability (paper §V: "applications and algorithm tasks
//! from three aspects"): linear algebra, signal processing and RL on the
//! standard WindMill, with CPU/GPU baseline ratios and simulator
//! throughput (the L3 perf metric tracked in EXPERIMENTS.md §Perf).
//!
//! `cargo bench --bench pea_throughput`

mod bench_util;

use std::time::Instant;

use bench_util::Table;
use windmill::arch::presets;
use windmill::coordinator::{run_all, JobSpec, Workload};
use windmill::util::stats::fmt_ns;

fn main() {
    let workloads = vec![
        Workload::Saxpy { n: 512 },
        Workload::Dot { n: 512 },
        Workload::Gemm { m: 32, n: 32, k: 32 },
        Workload::Fir { n: 512, taps: 16 },
        Workload::Conv3x3 { h: 32, w: 32 },
        Workload::RlStep,
    ];
    let specs: Vec<JobSpec> = workloads
        .into_iter()
        .map(|workload| JobSpec { workload, params: presets::standard(), seed: 42 })
        .collect();

    let t0 = Instant::now();
    let results = run_all(specs, 4);
    let wall = t0.elapsed();

    let mut t = Table::new(
        "cross-domain suite on standard WindMill (three aspects)",
        &["workload", "cycles", "II", "wm time", "vs CPU", "vs GPU", "PEs used"],
    );
    let mut total_cycles = 0u64;
    for r in &results {
        let r = r.as_ref().expect("job failed");
        total_cycles += r.cycles;
        t.row(&[
            r.name.clone(),
            r.cycles.to_string(),
            r.ii.to_string(),
            fmt_ns(r.wm_time_ns),
            format!("{:.1}x", r.speedup_vs_cpu),
            format!("{:.2}x", r.speedup_vs_gpu),
            r.mapped_nodes.to_string(),
        ]);
    }
    t.print();

    // Simulator throughput: the L3 hot-path metric for the perf pass.
    let sim_rate = total_cycles as f64 / wall.as_secs_f64();
    println!(
        "\nsimulator throughput: {total_cycles} machine cycles in {:.2}s wall = {:.0} cycles/s",
        wall.as_secs_f64(),
        sim_rate
    );
}
