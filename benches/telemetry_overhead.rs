//! Telemetry overhead bench (EXPERIMENTS.md §Telemetry): cycle-attributed
//! profiling must be close to free, or nobody leaves it on.
//!
//! Two acceptance gates, both asserted:
//!
//! 1. **Overhead.** A profiled cold GEMM sweep (stall attribution on,
//!    timeline off — the `--profile` CLI default) completes within 1.10x
//!    the unprofiled sweep's wall time (min over measured runs, so a
//!    single scheduler hiccup cannot fail the gate).
//! 2. **Trace export.** The Chrome `trace_event` document emitted for a
//!    timeline-profiled sweep parses as valid JSON and carries at least
//!    one counter event per PE row of the focus point, plus the pipeline
//!    spans — i.e. the file Perfetto loads is actually produced.
//!
//! `cargo bench --bench telemetry_overhead`

mod bench_util;

use bench_util::{bench, fmt_ns, Table};
use windmill::arch::params::ParamGrid;
use windmill::arch::presets;
use windmill::coordinator::{SweepEngine, SweepReport, Workload};
use windmill::sim::SimOptions;
use windmill::trace::chrome_trace;
use windmill::util::json::Json;

fn grid() -> ParamGrid {
    // Context-depth grid on the standard preset: every point mappable,
    // stage memoization identical on both arms (same kernel, same seed).
    ParamGrid::new(presets::standard()).context_depths(&[32, 48, 64, 96])
}

fn sweep(opts: Option<SimOptions>) -> SweepReport {
    let mut engine = SweepEngine::new(1);
    if let Some(o) = opts {
        engine = engine.with_profile(o);
    }
    let r = engine.sweep(&grid(), &Workload::Gemm { m: 16, n: 16, k: 16 });
    assert!(r.failures.is_empty(), "{:?}", r.failures);
    r
}

fn main() {
    // ---- gate 1: profiling overhead on a cold sweep ------------------------
    let off = bench(1, 3, || sweep(None).wall_ns);
    let on = bench(1, 3, || sweep(Some(SimOptions { profile: true, sample_stride: 0 })).wall_ns);

    let ratio = on.min() / off.min().max(1.0);
    let mut t = Table::new(
        "telemetry overhead: cold GEMM context-depth sweep (4 points)",
        &["path", "wall mean", "wall min", "vs off"],
    );
    t.row(&["profile off".into(), fmt_ns(off.mean()), fmt_ns(off.min()), "1.00x".into()]);
    t.row(&["profile on".into(), fmt_ns(on.mean()), fmt_ns(on.min()), format!("{ratio:.3}x")]);
    t.print();
    assert!(
        ratio <= 1.10,
        "profiled sweep must stay within 1.10x of unprofiled: {ratio:.3}x \
         ({} vs {})",
        fmt_ns(on.min()),
        fmt_ns(off.min())
    );

    // The profiled report actually carries verdicts on its frontier.
    let profiled = sweep(Some(SimOptions { profile: true, sample_stride: 0 }));
    let front = profiled.frontier_points();
    assert!(!front.is_empty());
    assert!(
        front.iter().all(|p| p.telemetry.is_some()),
        "every profiled frontier point must carry telemetry"
    );
    println!("profiled summary: {}", profiled.summary());

    // ---- gate 2: the Chrome trace is valid and row-complete ----------------
    let traced = sweep(Some(SimOptions { profile: true, sample_stride: 256 }));
    let doc = chrome_trace(&traced);
    let j = Json::parse(&doc).expect("trace must parse as JSON");
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    let name_of = |e: &Json| e.get("name").and_then(Json::as_str).unwrap_or("").to_string();
    assert!(
        events.iter().any(|e| name_of(e) == "simulate"),
        "pipeline spans missing from the trace"
    );

    let focus = traced
        .frontier_points()
        .into_iter()
        .find(|p| p.telemetry.as_ref().is_some_and(|t| !t.timeline.is_empty()))
        .expect("a timeline-profiled sweep must yield a focus point");
    let t = focus.telemetry.as_ref().unwrap();
    let rows = t.timeline[0].rows_fired.len();
    let banks = t.timeline[0].bank_conflicts.len();
    assert!(rows > 0 && banks > 0);
    for r in 0..rows {
        let track = format!("pe-row-{r}");
        assert!(
            events.iter().any(|e| name_of(e) == track),
            "trace must carry >=1 counter event for every PE row: missing {track}"
        );
    }
    for b in 0..banks {
        let track = format!("smem-bank-{b}");
        assert!(events.iter().any(|e| name_of(e) == track), "missing {track}");
    }
    println!(
        "trace export: {} events, {} PE-row tracks, {} bank tracks, {} bytes",
        events.len(),
        rows,
        banks,
        doc.len()
    );
    println!("telemetry-overhead acceptance: {ratio:.3}x <= 1.10x, trace valid");
}
