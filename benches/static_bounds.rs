//! Static-bounds bench (PR 10): the analyzer as a permanent oracle and a
//! near-free pre-sim gate.
//!
//! Two headline assertions on a Fig.-6-style grid (PEA edge × context
//! depth, four-kernel suite):
//!
//! 1. **Soundness**: every sweep point satisfies `bound <= cycles` — the
//!    resource-constrained lower bound never claims a cycle count the
//!    simulator can beat. This is the same invariant `tests/static_analysis.rs`
//!    spot-checks, asserted here across the whole grid via the report's new
//!    `bound` / `bound_gap` columns.
//! 2. **Cost**: a full static pass (`analysis::check` + `cycles_lower_bound`)
//!    over every compiled artifact of the grid costs <= 5% of the cold
//!    sweep's wall — linting the fabric is effectively free next to
//!    simulating it.
//!
//! `cargo bench --bench static_bounds`

mod bench_util;

use std::time::Instant;

use bench_util::{fmt_ns, Table};
use windmill::analysis;
use windmill::arch::params::ParamGrid;
use windmill::arch::presets;
use windmill::compiler::compile;
use windmill::coordinator::{calibrate_params, SweepEngine, WorkloadSuite};
use windmill::plugins;

const SEED: u64 = 42;

fn grid() -> ParamGrid {
    // Edges at or above the standard 8 and depths at or above the standard
    // 32: every suite kernel maps on every point, so the soundness sweep
    // has no holes.
    ParamGrid::new(presets::standard())
        .pea_edges(&[8, 12, 16])
        .context_depths(&[32, 64])
}

fn main() {
    let suite = WorkloadSuite::parse("saxpy,dot,fir,gemm").unwrap();

    // ---- cold sweep, wall-timed, bound column asserted sound ---------------
    let t0 = Instant::now();
    let report = SweepEngine::new(1).sweep_suite(&grid(), &suite, SEED);
    let sweep_ns = t0.elapsed().as_nanos() as u64;
    assert!(report.failures.is_empty(), "{:?}", report.failures);
    assert!(!report.points.is_empty(), "grid produced no points");

    let mut t = Table::new(
        "static lower bound vs simulated cycles (Fig.-6-style grid)",
        &["point", "cycles", "bound", "gap", "gap %"],
    );
    for p in &report.points {
        assert!(p.bound > 0, "{}: zero bound", p.label);
        assert!(
            p.bound <= p.cycles,
            "{}: bound {} exceeds simulated {} — the analyzer is unsound",
            p.label,
            p.bound,
            p.cycles
        );
        let gap = p.cycles - p.bound;
        t.row(&[
            p.label.clone(),
            p.cycles.to_string(),
            p.bound.to_string(),
            gap.to_string(),
            format!("{:.1}%", 100.0 * gap as f64 / p.cycles as f64),
        ]);
    }
    t.print();

    // ---- pure analyzer wall over the same artifacts ------------------------
    // Recompile the grid's artifacts untimed (the sweep already priced
    // compile+sim), then time nothing but the static passes.
    let mut artifacts = Vec::new();
    for (_label, params) in grid().points() {
        for workload in suite.workloads() {
            let (dfgs, layout) = workload.build();
            let calibrated = calibrate_params(params.clone(), &layout);
            let machine = plugins::elaborate(calibrated).unwrap().artifact;
            for dfg in dfgs {
                let mapping = compile(dfg, &machine, SEED).unwrap();
                artifacts.push((mapping, machine.clone()));
            }
        }
    }

    let t1 = Instant::now();
    let mut bound_sum = 0u64;
    for (mapping, machine) in &artifacts {
        let diags = analysis::check(mapping, machine);
        assert!(diags.is_empty(), "shipped artifact flagged: {diags:?}");
        bound_sum += analysis::cycles_lower_bound(mapping, machine);
    }
    let analyzer_ns = t1.elapsed().as_nanos() as u64;
    assert!(bound_sum > 0);

    println!(
        "analyzer wall: {} over {} artifacts vs cold sweep {} ({:.2}%)",
        fmt_ns(analyzer_ns as f64),
        artifacts.len(),
        fmt_ns(sweep_ns as f64),
        100.0 * analyzer_ns as f64 / sweep_ns as f64
    );
    assert!(
        analyzer_ns * 20 <= sweep_ns,
        "static pass must cost <= 5% of the cold sweep: {analyzer_ns} vs {sweep_ns} ns"
    );
    println!("static-bounds acceptance: bound sound on every grid point, analyzer <= 5% of sweep");
}
