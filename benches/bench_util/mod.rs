//! Shared micro-bench harness for the paper-figure benches (criterion is
//! not vendored on this image). Times a closure over warmup + measured
//! iterations and reports mean/p50/min, and carries the table printers the
//! EXPERIMENTS.md rows are pasted from.
#![allow(dead_code)]

use std::time::Instant;

pub use windmill::util::{stats::fmt_ns, Summary, Table};

/// Time `f` over `iters` measured runs (after `warmup` runs).
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        s.push(t0.elapsed().as_nanos() as f64);
    }
    s
}

/// Render a Summary as "mean ± stddev (min)".
pub fn fmt_summary(s: &mut Summary) -> String {
    format!("{} ± {} (min {})", fmt_ns(s.mean()), fmt_ns(s.stddev()), fmt_ns(s.min()))
}
