//! Fig. 6 (a)(b)(c): architecture-scalability sweeps of the generator.
//!
//! Regenerates the paper's scalability study: area as a function of PEA
//! size (strong), PE-type mix (strong), shared-memory size (moderate) and
//! interconnect topology (weak), plus generation wall-time per variant.
//!
//! `cargo bench --bench fig6_scalability`

mod bench_util;

use bench_util::{bench, fmt_summary, Table};
use windmill::arch::params::ParamGrid;
use windmill::arch::{presets, Topology};
use windmill::coordinator::{ppa_report, SweepEngine, Workload};
use windmill::plugins;

fn main() {
    // ---- Fig. 6a: PEA size ------------------------------------------------
    let mut t = Table::new(
        "Fig. 6a — area vs PEA size (standard PE mix, mesh)",
        &["pea", "gates", "area mm2", "rel. area", "fmax MHz", "power mW", "elaboration"],
    );
    let base_area = ppa_report("8", presets::with_pea_size(8)).unwrap().area_mm2;
    for edge in [2usize, 4, 6, 8, 12, 16, 24] {
        let params = presets::with_pea_size(edge);
        if params.validate().is_err() {
            continue;
        }
        let r = ppa_report(&format!("{edge}"), params.clone()).unwrap();
        let mut s = bench(1, 5, || plugins::elaborate(params.clone()).unwrap());
        t.row(&[
            format!("{edge}x{edge}"),
            format!("{:.3e}", r.gates),
            format!("{:.3}", r.area_mm2),
            format!("{:.2}x", r.area_mm2 / base_area),
            format!("{:.0}", r.fmax_mhz),
            format!("{:.2}", r.power_mw),
            fmt_summary(&mut s),
        ]);
    }
    t.print();

    // ---- Fig. 6b: PE-type mix ---------------------------------------------
    let mut t = Table::new(
        "Fig. 6b — area vs PE-type mix (8x8)",
        &["variant", "gates", "area mm2", "delta vs full"],
    );
    let full = ppa_report("full", presets::standard()).unwrap();
    let variants: Vec<(&str, Box<dyn Fn() -> windmill::arch::WindMillParams>)> = vec![
        ("GPE+LSU+CPE+SFU (std)", Box::new(presets::standard)),
        ("no SFU", Box::new(|| {
            let mut p = presets::standard();
            p.sfu_enabled = false;
            p
        })),
        ("no CPE", Box::new(|| {
            let mut p = presets::standard();
            p.cpe_enabled = false;
            p
        })),
        ("no SFU, no CPE", Box::new(|| {
            let mut p = presets::standard();
            p.sfu_enabled = false;
            p.cpe_enabled = false;
            p
        })),
    ];
    for (name, make) in variants {
        let r = ppa_report(name, make()).unwrap();
        t.row(&[
            name.to_string(),
            format!("{:.3e}", r.gates),
            format!("{:.3}", r.area_mm2),
            format!("{:+.1}%", 100.0 * (r.area_mm2 / full.area_mm2 - 1.0)),
        ]);
    }
    t.print();

    // ---- Fig. 6c: memory size and interconnect ----------------------------
    let mut t = Table::new(
        "Fig. 6c — area vs shared-memory size and topology",
        &["variant", "gates", "area mm2", "delta vs std", "fmax MHz"],
    );
    for (banks, depth) in [(8usize, 128usize), (16, 256), (32, 256), (32, 512), (64, 512)] {
        let r = ppa_report("sm", presets::with_smem(banks, depth)).unwrap();
        t.row(&[
            format!("smem {banks}x{depth}x32b"),
            format!("{:.3e}", r.gates),
            format!("{:.3}", r.area_mm2),
            format!("{:+.1}%", 100.0 * (r.area_mm2 / full.area_mm2 - 1.0)),
            format!("{:.0}", r.fmax_mhz),
        ]);
    }
    for topo in Topology::ALL {
        let r = ppa_report("t", presets::with_topology(topo)).unwrap();
        t.row(&[
            format!("topology {}", topo.name()),
            format!("{:.3e}", r.gates),
            format!("{:.3}", r.area_mm2),
            format!("{:+.1}%", 100.0 * (r.area_mm2 / full.area_mm2 - 1.0)),
            format!("{:.0}", r.fmax_mhz),
        ]);
    }
    t.print();

    println!(
        "\nshape check: PEA size & PE mix strong, memory moderate, topology weak —\n\
         matches the paper's Fig. 6 reading."
    );

    // ---- the whole study as one batched sweep ------------------------------
    // The sweep engine runs the full Fig. 6 grid (PEA size x topology) in
    // parallel with artifact caching, measures a fixed GEMM at every point,
    // and reports the best-PPA frontier — the agile-DSE workflow the paper
    // motivates, in one call.
    let engine = SweepEngine::new(4);
    let grid = ParamGrid::new(presets::standard())
        .pea_edges(&[4, 8, 12, 16])
        .topologies(&Topology::ALL);
    let workload = Workload::Gemm { m: 16, n: 16, k: 16 };
    let report = engine.sweep(&grid, &workload);
    report.table("Fig. 6 grid as one batched sweep (PEA size x topology)").print();
    println!("  {}", report.summary());
    println!("  pareto frontier:");
    for p in report.frontier_points() {
        println!(
            "    * {:<20} {:>7.3} mm2  {:>6.2} mW  {:>9} cycles",
            p.label, p.area_mm2, p.power_mw, p.cycles
        );
    }
    // Iterating on the study is nearly free on the warm cache.
    let warm = engine.sweep(&grid, &workload);
    println!(
        "  warm re-run: {:.1} ms wall ({:.0}% cache hits, was {:.1} ms cold)",
        warm.wall_ns as f64 / 1e6,
        100.0 * warm.cache_hit_rate(),
        report.wall_ns as f64 / 1e6
    );
}
