//! Fig. 6d: DIAG plugin productivity — "easy-plug heterogeneous
//! integration and agile productivity".
//!
//! Measures (1) elaboration wall-time as plugins are added bottom-up,
//! (2) the unplug→re-elaborate cycle (the agility loop an architect
//! iterates in), (3) zero-residue verification after detachment, and
//! (4) implementation-size proxies (netlist modules / service
//! registrations contributed per plugin).
//!
//! `cargo bench --bench fig6d_productivity`

mod bench_util;

use bench_util::{bench, fmt_summary, Table};
use windmill::arch::presets;
use windmill::netlist::NetlistStats;
use windmill::plugins::{self, fu::SfuFuPlugin};

fn main() {
    // ---- elaboration time vs plugin count (cumulative bottom-up) ---------
    let mut t = Table::new(
        "Fig. 6d — elaboration cost as the generator grows (bottom-up)",
        &["plugin set", "#plugins", "#modules", "services", "elaboration"],
    );
    // Ablate extensions progressively from the full standard generator.
    let steps: Vec<(&str, Box<dyn Fn() -> windmill::arch::WindMillParams>)> = vec![
        ("basic framework", Box::new(|| {
            let mut p = presets::standard();
            p.sfu_enabled = false;
            p.cpe_enabled = false;
            p.pingpong = false;
            p
        })),
        ("+ SFU", Box::new(|| {
            let mut p = presets::standard();
            p.cpe_enabled = false;
            p.pingpong = false;
            p
        })),
        ("+ CPE", Box::new(|| {
            let mut p = presets::standard();
            p.pingpong = false;
            p
        })),
        ("+ ping-pong DMA (full)", Box::new(presets::standard)),
    ];
    for (name, make) in steps {
        let params = make();
        let mut gen = plugins::generator(params.clone());
        let e = gen.elaborate().unwrap();
        let stats = NetlistStats::of(&e.netlist);
        let mut s = bench(1, 10, || plugins::elaborate(params.clone()).unwrap());
        t.row(&[
            name.to_string(),
            gen.plugin_count().to_string(),
            stats.module_defs.to_string(),
            e.service_registrations.to_string(),
            fmt_summary(&mut s),
        ]);
    }
    t.print();

    // ---- the agility loop: unplug + re-elaborate --------------------------
    let mut s = bench(1, 10, || {
        let mut gen = plugins::generator(presets::standard());
        gen.unplug("fu-sfu");
        gen.params_mut().sfu_enabled = false;
        let e = gen.elaborate().unwrap();
        // re-plug
        gen.params_mut().sfu_enabled = true;
        gen.plug(Box::new(SfuFuPlugin)).unwrap();
        let e2 = gen.elaborate().unwrap();
        (e.netlist.modules().len(), e2.netlist.modules().len())
    });
    println!("\nunplug -> elaborate -> re-plug -> elaborate: {}", fmt_summary(&mut s));

    // ---- zero-residue check ------------------------------------------------
    let mut gen = plugins::generator(presets::standard());
    gen.unplug("fu-sfu");
    gen.params_mut().sfu_enabled = false;
    let e = gen.elaborate().unwrap();
    let residue = e.netlist.by_provenance("fu-sfu").len()
        + e.netlist.find("fu_sfu").map_or(0, |_| 1);
    println!("residual artifacts after detaching fu-sfu: {residue} (must be 0)");
    assert_eq!(residue, 0);

    // ---- per-plugin contribution (implementation-size proxy) --------------
    let e = plugins::elaborate(presets::standard()).unwrap();
    let stats = NetlistStats::of(&e.netlist);
    let mut t = Table::new(
        "per-plugin contribution (modules / gates / stage time)",
        &["plugin", "gates contributed", "elaboration ns"],
    );
    let mut rows: Vec<(String, f64)> = stats.gates_by_plugin.clone().into_iter().collect();
    rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (plugin, gates) in rows {
        t.row(&[
            plugin.clone(),
            format!("{gates:.0}"),
            e.trace.per_plugin_nanos(&plugin).to_string(),
        ]);
    }
    t.print();
}
