//! End-to-end validation driver (the paper's headline RL experiment).
//!
//! Trains a 2-layer softmax policy with REINFORCE on a synthetic
//! pole-balancing environment for a few hundred steps:
//!
//! * **numerics** run through the AOT'd Layer-2 JAX artifact
//!   (`policy_step.hlo.txt`, Pallas matmuls inside) on the PJRT CPU
//!   runtime — Python is never invoked;
//! * the **same step** is periodically compiled onto the generated
//!   standard WindMill and cycle-counted by the simulator, its outputs
//!   cross-checked against the PJRT result;
//! * CPU (VexRiscv-class host) and GPU cost models price the baselines,
//!   reproducing the paper's §VI claim ("~200× vs CPU, 2.3× vs GPU").
//!
//! Run: `make artifacts && cargo run --release --example rl_accel`

use windmill::arch::presets;
use windmill::compiler::compile;
use windmill::coordinator::calibrate_params;
use windmill::model::baseline::{CpuModel, GpuModel};
use windmill::plugins;
use windmill::runtime::Runtime;
use windmill::sim::task::{run_task, Phase, Task};
use windmill::util::{stats::fmt_ns, Rng, Table};
use windmill::workloads::rl;

const ENVS: usize = 64; // = model.py BATCH
const OBS: usize = 4;
const ACTS: usize = 2;
const TRAIN_STEPS: usize = 300;
const EPISODE_CAP: u32 = 100;
const GAMMA: f32 = 0.97;

/// Synthetic pole-balancing environment (CartPole-like dynamics).
#[derive(Clone)]
struct PoleEnv {
    x: f32,
    v: f32,
    th: f32,
    om: f32,
    steps: u32,
}

impl PoleEnv {
    fn reset(rng: &mut Rng) -> Self {
        PoleEnv {
            x: rng.normal() * 0.05,
            v: rng.normal() * 0.05,
            th: rng.normal() * 0.05,
            om: rng.normal() * 0.05,
            steps: 0,
        }
    }

    fn obs(&self) -> [f32; OBS] {
        [self.x, self.v, self.th, self.om]
    }

    /// Returns (reward, done).
    fn step(&mut self, action: usize) -> (f32, bool) {
        let force = if action == 1 { 1.0 } else { -1.0 };
        let dt = 0.02;
        // Linearized cart-pole.
        let th_acc = 9.8 * self.th.sin() * 3.0 + force * -1.5;
        let x_acc = force * 1.0 - self.th * 0.5;
        self.v += x_acc * dt;
        self.x += self.v * dt;
        self.om += th_acc * dt;
        self.th += self.om * dt;
        self.steps += 1;
        let done =
            self.x.abs() > 2.4 || self.th.abs() > 0.21 || self.steps >= EPISODE_CAP;
        (1.0, done)
    }
}

struct Params {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
}

fn softmax2(l0: f32, l1: f32) -> (f32, f32) {
    let m = l0.max(l1);
    let (e0, e1) = ((l0 - m).exp(), (l1 - m).exp());
    let s = e0 + e1;
    (e0 / s, e1 / s)
}

fn main() -> windmill::Result<()> {
    println!("== WindMill RL end-to-end (REINFORCE on synthetic pole balancing) ==");
    let mut rt = Runtime::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
    println!("PJRT platform: {}", rt.platform());

    let hidden = rt.manifest.shape_const("hidden").unwrap_or(32.0) as usize;
    let mut rng = Rng::new(2024);
    let mut params = Params {
        w1: (0..OBS * hidden).map(|_| rng.normal() * 0.3).collect(),
        b1: vec![0.0; hidden],
        w2: (0..hidden * ACTS).map(|_| rng.normal() * 0.3).collect(),
        b2: vec![0.0; ACTS],
    };

    // Elaborate the accelerator once; the RL step defines the memory need
    // (Generation→Definition calibration loop).
    let step_dfgs = rl::policy_step();
    let wm_params = calibrate_params(presets::standard(), &step_dfgs.layout);
    let machine = plugins::elaborate(wm_params)?.artifact;
    let mappings: Vec<_> = step_dfgs
        .phases
        .iter()
        .map(|d| compile(d.clone(), &machine, 42))
        .collect::<Result<_, _>>()?;
    let n_ph = mappings.len();
    let task = Task {
        name: "rl-step".into(),
        phases: mappings
            .into_iter()
            .enumerate()
            .map(|(i, mapping)| Phase {
                mapping: std::sync::Arc::new(mapping),
                dma_in_words: if i == 0 {
                    (ENVS * (OBS + ACTS + 1)) as u64 // obs+onehot+returns per step
                } else {
                    0
                },
                dma_out_words: if i + 1 == n_ph { 1 } else { 0 }, // loss readback
            })
            .collect(),
    };

    let mut envs: Vec<PoleEnv> = (0..ENVS).map(|_| PoleEnv::reset(&mut rng)).collect();
    let mut ep_rewards = vec![0.0f32; ENVS];
    let mut finished_returns: Vec<f32> = Vec::new();
    // Replay of (obs, action, reward-index) per env for reward-to-go.
    let mut traj: Vec<Vec<([f32; OBS], usize)>> = vec![Vec::new(); ENVS];
    let mut buffer: Vec<([f32; OBS], usize, f32)> = Vec::new();

    let mut loss_curve: Vec<(usize, f32, f32)> = Vec::new();
    let mut wm_cycles_per_step = 0u64;
    let mut sim_checks = 0usize;
    let mut pjrt_ns_sum = 0.0;

    for step in 0..TRAIN_STEPS {
        // ---- collect one batched env step through the policy ------------
        let obs_batch: Vec<f32> = envs.iter().flat_map(|e| e.obs()).collect();
        let (out, _) = rt.execute_timed(
            "policy_forward",
            &[params.w1.clone(), params.b1.clone(), params.w2.clone(), params.b2.clone(), obs_batch.clone()],
        )?;
        let logits = &out[0];
        for i in 0..ENVS {
            let (p0, _p1) = softmax2(logits[2 * i], logits[2 * i + 1]);
            let action = if rng.f32() < p0 { 0 } else { 1 };
            traj[i].push((envs[i].obs(), action));
            let (r, done) = envs[i].step(action);
            ep_rewards[i] += r;
            if done {
                // Reward-to-go with discounting, pushed into the buffer.
                let t_len = traj[i].len();
                let mut g = 0.0f32;
                for (k, (o, a)) in traj[i].drain(..).enumerate().rev() {
                    let _ = k;
                    g = 1.0 + GAMMA * g;
                    buffer.push((o, a, g));
                    if t_len > 0 {}
                }
                finished_returns.push(ep_rewards[i]);
                ep_rewards[i] = 0.0;
                envs[i] = PoleEnv::reset(&mut rng);
            }
        }

        // ---- train when the buffer holds a full batch --------------------
        if buffer.len() < ENVS {
            continue;
        }
        let batch: Vec<([f32; OBS], usize, f32)> = buffer.drain(..ENVS).collect();
        let mean_g: f32 = batch.iter().map(|b| b.2).sum::<f32>() / ENVS as f32;
        let std_g: f32 = (batch.iter().map(|b| (b.2 - mean_g).powi(2)).sum::<f32>()
            / ENVS as f32)
            .sqrt()
            .max(1e-3);
        let obs_b: Vec<f32> = batch.iter().flat_map(|b| b.0).collect();
        let onehot: Vec<f32> = batch
            .iter()
            .flat_map(|b| if b.1 == 0 { [1.0, 0.0] } else { [0.0, 1.0] })
            .collect();
        let returns: Vec<f32> = batch.iter().map(|b| (b.2 - mean_g) / std_g).collect();

        let inputs = vec![
            params.w1.clone(),
            params.b1.clone(),
            params.w2.clone(),
            params.b2.clone(),
            obs_b.clone(),
            onehot.clone(),
            returns.clone(),
        ];
        let (out, ns) = rt.execute_timed("policy_step", &inputs)?;
        pjrt_ns_sum += ns;
        let loss = out[4][0];
        params.w1 = out[0].clone();
        params.b1 = out[1].clone();
        params.w2 = out[2].clone();
        params.b2 = out[3].clone();

        let recent: f32 = if finished_returns.is_empty() {
            0.0
        } else {
            let tail = &finished_returns[finished_returns.len().saturating_sub(20)..];
            tail.iter().sum::<f32>() / tail.len() as f32
        };
        loss_curve.push((step, loss, recent));
        if loss_curve.len() % 25 == 1 {
            println!(
                "step {step:4}  loss {loss:+.4}  mean episode return (last 20) {recent:6.1}"
            );
        }

        // ---- periodically run the SAME step on the simulated WindMill ---
        if sim_checks < 3 {
            let l = &step_dfgs.layout;
            let mut mem = vec![0.0f32; machine.smem.as_ref().unwrap().words()];
            l.fill(&mut mem, "obs", &obs_b);
            l.fill(&mut mem, "w1", &inputs[0]);
            l.fill(&mut mem, "b1", &inputs[1]);
            l.fill(&mut mem, "w2", &inputs[2]);
            l.fill(&mut mem, "b2", &inputs[3]);
            l.fill(&mut mem, "onehot", &onehot);
            l.fill(&mut mem, "returns", &returns);
            let tr = run_task(&task, &machine, &mem, 8_000_000)?;
            wm_cycles_per_step = tr.total_cycles;
            // Cross-check the simulated update against the PJRT output.
            let mut max_err = 0.0f32;
            for (name, want) in
                [("w1", &out[0]), ("b1", &out[1]), ("w2", &out[2]), ("b2", &out[3])]
            {
                for (a, b) in l.read(&tr.mem, name).iter().zip(want.iter()) {
                    max_err = max_err.max((a - b).abs());
                }
            }
            let sim_loss = l.read(&tr.mem, "loss")[0];
            max_err = max_err.max((sim_loss - loss).abs());
            assert!(
                max_err < 5e-3,
                "simulated WindMill update diverged from PJRT golden: {max_err}"
            );
            println!(
                "  [sim-check {sim_checks}] WindMill cycles/step = {} (compute {}, dma-exposed {}, host {}), max |err| vs PJRT = {max_err:.2e}",
                tr.total_cycles, tr.compute_cycles, tr.dma_cycles_exposed, tr.host_cycles
            );
            sim_checks += 1;
        }
    }

    // ---- summary ---------------------------------------------------------
    let wm_ns = wm_cycles_per_step as f64 * machine.cycle_ns();
    let cpu = CpuModel::default();
    let cpu_ns = cpu.time_ns(&step_dfgs.op_counts());
    let gpu = GpuModel::default();
    let gpu_ns = gpu.time_ns(
        step_dfgs.flops(),
        (rl::BATCH * rl::ACT) as f64,
        step_dfgs.gpu_kernels(),
        step_dfgs.layout.total_words() as f64 * 4.0,
    );

    let first = loss_curve.first().map(|x| x.2).unwrap_or(0.0);
    let last = loss_curve.last().map(|x| x.2).unwrap_or(0.0);
    println!("\nloss curve: {} training steps logged", loss_curve.len());
    println!("mean episode return: {first:.1} -> {last:.1} (learning confirmed: {})", last > first);

    let mut t = Table::new(
        "RL step: WindMill vs baselines (paper §VI: ~200x CPU, 2.3x GPU)",
        &["executor", "time / step", "speedup vs WindMill=1"],
    );
    t.row(&["WindMill 8x8 @750 MHz (simulated)".into(), fmt_ns(wm_ns), "1.00x".into()]);
    t.row(&[
        "host CPU (VexRiscv-class model)".into(),
        fmt_ns(cpu_ns),
        format!("{:.1}x slower", cpu_ns / wm_ns),
    ]);
    t.row(&[
        "GPU (small-batch launch model)".into(),
        fmt_ns(gpu_ns),
        format!("{:.2}x slower", gpu_ns / wm_ns),
    ]);
    t.row(&[
        "PJRT CPU wallclock (this host, reference)".into(),
        fmt_ns(pjrt_ns_sum / loss_curve.len().max(1) as f64),
        "-".into(),
    ]);
    t.print();
    println!(
        "\npaper: 200x vs CPU -> measured {:.0}x; 2.3x vs GPU -> measured {:.2}x",
        cpu_ns / wm_ns,
        gpu_ns / wm_ns
    );
    Ok(())
}
