//! Plugin laboratory: the DIAG "easy-plug" demonstration (paper Fig. 3 and
//! Fig. 6d). Detach plugins from the standard WindMill generator and show:
//!
//! 1. service chains re-bind around the hole (A→B→C becomes A→C),
//! 2. the generated netlist carries **zero residual logic** from the
//!    detached plugin,
//! 3. capability sets / machine description follow the plugin set,
//! 4. re-plugging restores the original design byte-for-byte.
//!
//! `cargo run --release --example plugin_lab`

use windmill::arch::presets;
use windmill::netlist::{verilog, NetlistStats};
use windmill::plugins::{self, fu::SfuFuPlugin, mem::DmaPlugin};

fn main() -> windmill::Result<()> {
    // Baseline.
    let mut gen = plugins::generator(presets::standard());
    println!("standard plugin set ({}): {:?}\n", gen.plugin_count(), gen.plugin_names());
    let base = gen.elaborate()?;
    let base_stats = NetlistStats::of(&base.netlist);
    let base_verilog = verilog::emit(&base.netlist);
    println!(
        "baseline: {} modules, {:.0} gates, {} service registrations",
        base_stats.module_defs, base_stats.total_gates, base.service_registrations
    );

    // ---- detach the SFU (an execute-stage FU in the Fig. 3 chain) --------
    assert!(gen.unplug("fu-sfu"));
    gen.params_mut().sfu_enabled = false;
    let no_sfu = gen.elaborate()?;
    let no_sfu_stats = NetlistStats::of(&no_sfu.netlist);
    println!("\n-- unplug `fu-sfu` --");
    println!(
        "modules {} -> {}; gates {:.0} -> {:.0}",
        base_stats.module_defs,
        no_sfu_stats.module_defs,
        base_stats.total_gates,
        no_sfu_stats.total_gates
    );
    assert!(no_sfu.netlist.find("fu_sfu").is_none(), "residual SFU module!");
    assert!(no_sfu.netlist.by_provenance("fu-sfu").is_empty(), "residual provenance!");
    assert!(
        no_sfu.skipped_extensions.contains(&"pe/fu/sfu".to_string()),
        "definition layer should report the skipped extension"
    );
    // The GPE execute chain re-bound: ALU -> MUL only.
    let gpe = no_sfu.netlist.find("pe_gpe").unwrap();
    let fu_insts: Vec<&str> = gpe
        .instances
        .iter()
        .filter(|i| i.module.starts_with("fu_"))
        .map(|i| i.module.as_str())
        .collect();
    println!("GPE execute chain is now {fu_insts:?} (was [fu_alu, fu_mul, fu_sfu])");
    assert_eq!(fu_insts, ["fu_alu", "fu_mul"]);

    // ---- detach the ping-pong DMA (a memory-path extension) --------------
    gen.params_mut().sfu_enabled = true;
    gen.plug(Box::new(SfuFuPlugin))?;
    assert!(gen.unplug("dma"));
    gen.params_mut().pingpong = false;
    let no_dma = gen.elaborate()?;
    println!("\n-- unplug `dma` --");
    assert!(no_dma.netlist.find("dma").is_none());
    assert!(no_dma.artifact.dma.is_none());
    let rca = no_dma.netlist.find("rca").unwrap();
    assert!(
        rca.instances.iter().all(|i| i.module != "dma"),
        "RCA must not instantiate the detached DMA"
    );
    println!("RCA assembles without the DMA; machine description has dma=None");

    // ---- re-plug: byte-identical regeneration -----------------------------
    gen.params_mut().pingpong = true;
    gen.plug(Box::new(DmaPlugin))?;
    let restored = gen.elaborate()?;
    let restored_verilog = verilog::emit(&restored.netlist);
    println!("\n-- re-plug `dma`, `fu-sfu` --");
    println!(
        "regenerated Verilog identical to baseline: {}",
        restored_verilog == base_verilog
    );
    assert_eq!(restored_verilog, base_verilog);

    // ---- productivity: elaboration cost per plugin (Fig. 6d flavour) -----
    println!("\nper-plugin elaboration time (ns):");
    let mut rows: Vec<(String, u128)> = restored
        .trace
        .events
        .iter()
        .map(|e| (e.plugin.clone(), 0u128))
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    for (name, ns) in rows.iter_mut() {
        *ns = restored.trace.per_plugin_nanos(name);
    }
    rows.sort_by_key(|(_, ns)| std::cmp::Reverse(*ns));
    for (name, ns) in rows.iter().take(8) {
        println!("  {name:14} {ns:>10}");
    }
    println!("\nplugin_lab OK");
    Ok(())
}
