//! Design-space exploration through the cache-backed sweep engine: the
//! Fig. 6 parameter sweeps as one batched study — PEA size, PE-type mix,
//! interconnect topology and shared-memory size, each point priced for
//! area / fmax / power *and* measured on a fixed GEMM workload — plus the
//! best-PPA Pareto frontier and the cache economics that make iterating on
//! the grid cheap.
//!
//! `cargo run --release --example design_space`

use windmill::arch::params::ParamGrid;
use windmill::arch::{presets, Topology};
use windmill::coordinator::{SweepEngine, Workload};

fn main() -> windmill::Result<()> {
    let engine = SweepEngine::new(4);
    let workload = Workload::Gemm { m: 16, n: 16, k: 16 };

    // --- Fig. 6a: PEA size (strong area effect) ---------------------------
    let grid = ParamGrid::new(presets::standard()).pea_edges(&[4, 6, 8, 12, 16]);
    let report = engine.sweep(&grid, &workload);
    report.table("Fig. 6a analog: PEA size sweep (strong area effect)").print();
    println!("  {}\n", report.summary());

    // --- Fig. 6b: PE-type mix (SFU x CPE ablations) -----------------------
    // GEMM needs no SFU/CPE, so all four points map; the area deltas of
    // unplugging each extension are the paper's Fig. 6b reading.
    let grid = ParamGrid::new(presets::standard()).sfu(&[true, false]).cpe(&[true, false]);
    let report = engine.sweep(&grid, &workload);
    report.table("Fig. 6b analog: PE-type mix (strong area effect)").print();
    println!("  {}\n", report.summary());

    // --- Fig. 6c: interconnect (weak) × memory size (moderate) ------------
    let grid = ParamGrid::new(presets::standard())
        .topologies(&Topology::ALL)
        .smem_geoms(&[(8, 128), (16, 256), (32, 512)]);
    let report = engine.sweep(&grid, &workload);
    report
        .table("Fig. 6c analog: topology (weak area effect) x shared memory")
        .print();
    println!("  {}", report.summary());

    // The topology×smem grid shares every architecture dimension pairwise
    // with the earlier sweeps' standard point, and the workload is fixed —
    // the cache turns the combined study into incremental work.
    println!("\nbest-PPA Pareto frontier of the topology x memory sweep:");
    for p in report.frontier_points() {
        println!(
            "  * {:<24} {:>7.3} mm2  {:>6.2} mW  {:>9} cycles",
            p.label, p.area_mm2, p.power_mw, p.cycles
        );
    }
    if let Some(best) = report.best_performance() {
        println!("fastest point on GEMM: {} ({} cycles)", best.label, best.cycles);
    }

    // --- iterating is where the engine earns its keep ---------------------
    // Re-running the full Fig. 6c grid (e.g. after editing the analysis)
    // answers from the artifact cache.
    let again = engine.sweep(&grid, &workload);
    println!(
        "\nre-run of the Fig. 6c grid: {:.1} ms wall, cache hit rate {:.0}%",
        again.wall_ns as f64 / 1e6,
        100.0 * again.cache_hit_rate()
    );

    println!(
        "\nReading: PEA size and PE mix dominate area; topology moves area by <2%\n\
         but shifts fmax — matching the paper's Fig. 6 conclusions."
    );
    Ok(())
}
