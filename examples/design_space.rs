//! Design-space exploration: the Fig. 6 parameter sweeps as one runnable
//! study — PEA size, PE-type mix, interconnect topology and shared-memory
//! size against area / fmax / power, plus the performance effect on a
//! fixed workload. Demonstrates the "quantitative parameterized
//! architecture" side of the generator.
//!
//! `cargo run --release --example design_space`

use windmill::arch::{presets, Topology};
use windmill::coordinator::{ppa_report, run_job, JobSpec, Workload};
use windmill::util::{table, Table};

fn main() -> anyhow::Result<()> {
    // --- Fig. 6a: area vs PEA size ----------------------------------------
    let mut t = Table::new(
        "Fig. 6a analog: PEA size sweep (strong area effect)",
        &["pea", "gates", "area mm2", "fmax MHz", "power mW", "gemm cycles"],
    );
    for edge in [4usize, 6, 8, 12, 16] {
        let p = presets::with_pea_size(edge);
        let r = ppa_report(&format!("{edge}x{edge}"), p.clone())?;
        let job = run_job(&JobSpec {
            workload: Workload::Gemm { m: 16, n: 16, k: 16 },
            params: p,
            seed: 3,
        })?;
        t.row(&[
            r.pea,
            format!("{:.2e}", r.gates),
            table::f(r.area_mm2, 3),
            table::f(r.fmax_mhz, 0),
            table::f(r.power_mw, 2),
            job.cycles.to_string(),
        ]);
    }
    t.print();

    // --- Fig. 6b: PE-type mix (SFU / CPE / LSU-ring ablations) ------------
    let mut t = Table::new(
        "Fig. 6b analog: PE-type mix (strong area effect)",
        &["variant", "gates", "area mm2", "note"],
    );
    let mut base = presets::standard();
    let full = ppa_report("full", base.clone())?;
    t.row(&[
        "GPE+LSU+CPE+SFU".into(),
        format!("{:.2e}", full.gates),
        table::f(full.area_mm2, 3),
        "standard".into(),
    ]);
    base.sfu_enabled = false;
    let nosfu = ppa_report("nosfu", base.clone())?;
    t.row(&[
        "no SFU".into(),
        format!("{:.2e}", nosfu.gates),
        table::f(nosfu.area_mm2, 3),
        format!("-{:.1}% area", 100.0 * (1.0 - nosfu.area_mm2 / full.area_mm2)),
    ]);
    base.sfu_enabled = true;
    base.cpe_enabled = false;
    let nocpe = ppa_report("nocpe", base.clone())?;
    t.row(&[
        "no CPE".into(),
        format!("{:.2e}", nocpe.gates),
        table::f(nocpe.area_mm2, 3),
        format!("-{:.1}% area", 100.0 * (1.0 - nocpe.area_mm2 / full.area_mm2)),
    ]);
    t.print();

    // --- Fig. 6c: interconnect (weak) + memory size (moderate) ------------
    let mut t = Table::new(
        "Fig. 6c analog: interconnect topology (weak area effect) & memory",
        &["variant", "gates", "area mm2", "fmax MHz"],
    );
    for topo in Topology::ALL {
        let r = ppa_report(topo.name(), presets::with_topology(topo))?;
        t.row(&[
            format!("topology {}", r.topology),
            format!("{:.2e}", r.gates),
            table::f(r.area_mm2, 3),
            table::f(r.fmax_mhz, 0),
        ]);
    }
    for (banks, depth) in [(8usize, 128usize), (16, 256), (32, 512)] {
        let r = ppa_report(&format!("sm{banks}x{depth}"), presets::with_smem(banks, depth))?;
        t.row(&[
            format!("smem {banks}x{depth}x32b"),
            format!("{:.2e}", r.gates),
            table::f(r.area_mm2, 3),
            table::f(r.fmax_mhz, 0),
        ]);
    }
    t.print();

    println!(
        "\nReading: PEA size and PE mix dominate area; topology moves area by <2%\n\
         but shifts fmax — matching the paper's Fig. 6 conclusions."
    );
    Ok(())
}
