//! Quickstart: generate a WindMill, look at its PPA, run a kernel.
//!
//! `cargo run --release --example quickstart`

use windmill::arch::presets;
use windmill::coordinator::{ppa_report, run_job, JobSpec, Workload};
use windmill::netlist::verilog;
use windmill::plugins;

fn main() -> windmill::Result<()> {
    // 1. Elaborate the paper's standard WindMill through the DIAG flow.
    let elaborated = plugins::elaborate(presets::standard())?;
    println!(
        "elaborated `windmill_top`: {} module definitions, {} extension fragments skipped",
        elaborated.netlist.modules().len(),
        elaborated.skipped_extensions.len()
    );

    // 2. Emit Verilog (first lines shown; `windmill generate` dumps it all).
    let v = verilog::emit(&elaborated.netlist);
    for line in v.lines().take(8) {
        println!("  | {line}");
    }
    println!("  | ... ({} lines total)", v.lines().count());

    // 3. PPA report against the paper's 750 MHz / 16.15 mW anchors.
    let row = ppa_report("standard", presets::standard())?;
    println!(
        "\nPPA: {:.2} mm² ({:.0} gates + {:.0} KiB SRAM), fmax {:.0} MHz, {:.2} mW",
        row.area_mm2, row.gates, row.sram_kib, row.fmax_mhz, row.power_mw
    );

    // 4. Map and simulate a GEMM on the array, vs the host-CPU baseline.
    let job = JobSpec {
        workload: Workload::Gemm { m: 16, n: 16, k: 16 },
        params: presets::standard(),
        seed: 7,
    };
    let r = run_job(&job)?;
    println!(
        "\nGEMM 16x16x16: {} cycles on the 8x8 PEA (II={}), {:.1}x faster than the host CPU",
        r.cycles, r.ii, r.speedup_vs_cpu
    );
    Ok(())
}
